"""Simulated serverless platform (Knative-shaped) for experiments.

Models the pieces of Knative that the paper's evaluation depends on:

* **Containers** with a concurrency limit (``containerConcurrency``); a
  batched request occupies one concurrency slot for its service time (the
  ML serving containers in the paper process requests serially).
* **KPA autoscaler**: concurrency-based scaling with a stable window, a
  panic window, target utilization, scale-to-zero after a grace period and
  cold-start delay for new containers.
* **Activator queue**: requests (batches) that arrive when no slot is free
  queue platform-side; their queueing time is part of the upstream response
  time the proxy's monitor observes — exactly what MLProxy sees through its
  HTTP client.
* **Billing**: cost is a billable-seconds *integral* — billable
  (provisioned or draining) containers integrated over time, exposed as
  :attr:`ServerlessPlatform.cost_integral` — not a point-in-time container
  count. The paper's "number of containers" figure is this integral
  divided by the billing window (:meth:`ServerlessPlatform.avg_containers`).
* **Fault injection** (beyond paper, required at production scale): random
  container crashes with at-least-once re-dispatch, straggler service
  times, spot-style container preemption (a billable container reclaimed
  mid-batch), and optional hedged duplicates for straggler mitigation.

Execution is organised around an explicit **attempt ledger**: every
:class:`_WorkItem` (one upstream batch) owns the set of its live
:class:`_Attempt` records — (container, start time, scheduled completion) —
and every state transition (crash, completion, hedge, drain, scale-down)
resolves through the ledger:

* a container crash cancels and requeues *every* live attempt on the dead
  container, so co-resident batches are never lost when
  ``container_concurrency > 1``;
* the first completed attempt wins; sibling attempts are cancelled on the
  spot, freeing their concurrency slots immediately (no phantom occupancy
  until a stale completion timer fires);
* hedged duplicates are capped per item (``max_hedges``) and placed
  anti-affine to the item's live attempts, so one straggler cannot fan out
  into a duplicate storm on the same doomed container;
* the autoscaler's concurrency signal is derived from the ledger (live
  attempts + queued-not-done items), so completed items lingering in the
  queue never inflate it.

The ledger makes the conservation invariant checkable at any instant:
``submitted == completed + queued + inflight`` with zero lost and zero
duplicate completions — see :meth:`ServerlessPlatform.conservation` and
:meth:`ServerlessPlatform.assert_conserved`.

The platform is clock-free like the proxy: it schedules itself on the
shared :class:`~repro.simulation.events.EventQueue`.
"""
from __future__ import annotations

import collections
import dataclasses
import heapq
import itertools
import math
from functools import partial
from typing import Callable, Deque, Dict, List, Optional, Tuple

import numpy as np

from repro.core.request import Batch
from repro.serverless.latency import LatencyModel
from repro.simulation.events import EventQueue


@dataclasses.dataclass(frozen=True)
class PlatformConfig:
    container_concurrency: int = 1
    target_utilization: float = 0.7
    autoscale_tick: float = 2.0
    metric_tick: float = 1.0
    stable_window: float = 60.0
    panic_window: float = 6.0
    panic_threshold: float = 2.0
    scale_to_zero_grace: float = 30.0
    cold_start: float = 4.0
    min_scale: int = 0
    max_scale: int = 1000
    initial_scale: int = 0
    # Knative rate limits: desired ≤ up_rate × current per tick, and
    # desired ≥ current / down_rate per tick.
    max_scale_up_rate: float = 10.0
    max_scale_down_rate: float = 2.0
    # Processor-sharing slowdown: with k batches co-resident on one
    # container, each takes ×(1 + ps_slowdown·(k−1)) longer (CPU-bound ML
    # containers serialize; 1.0 ≈ perfect processor sharing).
    ps_slowdown: float = 1.0
    # Fault injection / mitigation (beyond paper)
    failure_prob_per_batch: float = 0.0
    # Spot-style preemption: per-attempt probability that the hosting
    # container is reclaimed mid-service. Like a crash, every co-resident
    # attempt is requeued through the ledger, but the accounting is kept
    # separate (capacity taken back by the platform, not lost to a fault).
    preempt_prob_per_batch: float = 0.0
    straggler_prob: float = 0.0
    straggler_mult: float = 5.0
    hedge_factor: float = 0.0  # >0 enables hedged re-dispatch at f×E[s]
    max_hedges: int = 1  # cap on hedged duplicates per work item


class _Container:
    _ids = itertools.count()
    __slots__ = ("cid", "ready_at", "terminated", "draining", "inflight",
                 "attempts", "counted_ready", "in_heap")

    def __init__(self, ready_at: float) -> None:
        self.cid = next(_Container._ids)
        self.ready_at = ready_at
        self.terminated = False
        self.draining = False  # finish in-flight work then terminate
        self.inflight: int = 0
        self.attempts: List["_Attempt"] = []  # live attempts hosted here
        # Bookkeeping for the O(1) counters / free-heap (see platform):
        # counted_ready mirrors "ready and not draining" exactly as of the
        # last state transition; in_heap marks membership in the free-heap.
        self.counted_ready = False
        self.in_heap = False

    def is_ready(self, now: float) -> bool:
        return not self.terminated and now >= self.ready_at

    def available_slots(self, now: float, concurrency: int) -> int:
        if not self.is_ready(now) or self.draining:
            return 0
        return max(0, concurrency - self.inflight)


class _Attempt:
    """One dispatch of a work item onto a container.

    ``resolved`` flips exactly once — on completion, cancellation (a
    sibling won), or crash — so the completion/crash/hedge events queued
    against this attempt become no-ops the moment it leaves the ledger.
    """

    _ids = itertools.count()
    __slots__ = ("attempt_id", "item", "container", "start", "eta", "resolved")

    def __init__(self, item: "_WorkItem", container: _Container,
                 start: float, eta: float) -> None:
        self.attempt_id = next(_Attempt._ids)
        self.item = item
        self.container = container
        self.start = start
        self.eta = eta  # scheduled completion (or crash instant if doomed)
        self.resolved = False


class _WorkItem:
    _ids = itertools.count()
    __slots__ = ("item_id", "batch", "submit_time", "done", "attempts",
                 "hedges", "live", "queued")

    def __init__(self, batch: Batch, submit_time: float) -> None:
        self.item_id = next(_WorkItem._ids)
        self.batch = batch
        self.submit_time = submit_time
        self.done = False
        self.attempts = 0  # total attempts ever started
        self.hedges = 0  # hedged duplicates issued (capped by max_hedges)
        self.live: List[_Attempt] = []  # unresolved attempts
        self.queued = False  # logically in the pending queue


class ServerlessPlatform:
    """Discrete-event Knative-like platform fed by a batching policy."""

    def __init__(
        self,
        config: PlatformConfig,
        latency_model: LatencyModel,
        events: EventQueue,
        rng: np.random.Generator,
        on_batch_done: Callable[[Batch, float, float], None],
        fault_rng: Optional[np.random.Generator] = None,
        tracer=None,
        recorder=None,
    ) -> None:
        """``on_batch_done(batch, upstream_latency, now)`` fires once per batch.

        ``rng`` draws service times; ``fault_rng`` (defaulting to the same
        stream) draws crash/straggler outcomes. The simulator passes two
        spawned streams so fault injection cannot shift service-time draws
        (and vice versa) when either path changes.

        ``tracer``/``recorder`` are the optional observability seams (see
        :mod:`repro.obs`): the tracer receives one span per ledger
        transition (attempt / fault / hedge / completion), the recorder's
        ring is dumped when :meth:`assert_conserved` trips. Both default
        to None, which keeps the hot path untouched.
        """
        self.config = config
        self.latency = latency_model
        self.events = events
        self.rng = rng
        self.fault_rng = fault_rng if fault_rng is not None else rng
        self.on_batch_done = on_batch_done
        self.tracer = tracer
        self.recorder = recorder

        self.containers: List[_Container] = []
        self.pending: Deque[_WorkItem] = collections.deque()
        # O(1) fleet counters (maintained at every container transition;
        # replaces the per-event list scans that dominated large runs) and
        # the cid-ordered heap of containers that may have a free slot.
        self._n_provisioned = 0
        self._n_billable = 0
        self._n_ready = 0
        self._free_heap: List[Tuple[int, _Container]] = []
        self._queued_count = 0  # live (not-done) items in ``pending``
        self._live_attempts = 0  # unresolved attempts across all containers
        self._open: Dict[int, _WorkItem] = {}  # item_id → not-yet-done item
        # time-weighted concurrency (Knative's queue-proxy reports average
        # concurrency over each reporting period, not point samples —
        # point-sampling misses sub-second batches and flaps the panic mode)
        self._conc_samples: Deque[Tuple[float, float]] = collections.deque()
        self._conc_integral = 0.0
        self._conc_t = 0.0
        self._last_traffic: float = 0.0
        self._panic_until: float = -1.0
        self._started = False

        # billing + metrics
        self.container_seconds = 0.0
        self._billing_last_t = 0.0
        self._billing_last_n = 0
        self.submitted_batches = 0
        self.submitted_requests = 0
        self.completed_batches = 0
        self.completed_requests = 0
        self.failed_attempts = 0
        self.preemptions = 0  # billable containers reclaimed mid-batch
        self.preempted_attempts = 0  # live attempts cancelled by reclaims
        self.requeued_batches = 0  # crash/preempt at-least-once requeues
        self.hedged_dispatches = 0
        self.cancelled_attempts = 0  # sibling attempts cancelled by a winner
        self.duplicate_completions = 0  # must stay 0: exactly-once guard
        self.cold_starts = 0
        self.peak_containers = 0
        self.timeline: List[Tuple[float, int, int, int]] = []  # (t, provisioned, ready, queued)

        for _ in range(max(config.min_scale, config.initial_scale)):
            self._start_container(0.0, cold=False)

    # ------------------------------------------------------------------ api
    def start(self, now: float) -> None:
        """Begin autoscaler + metric ticking."""
        if self._started:
            return
        self._started = True
        self._billing_last_t = now
        self.events.push(now + self.config.metric_tick, self._metric_tick)
        self.events.push(now + self.config.autoscale_tick, self._autoscale_tick)

    def submit(self, batch: Batch, now: float) -> None:
        """One upstream HTTP request carrying ``batch`` (the proxy's view)."""
        self.start(now)
        self._accrue_conc(now)
        self._last_traffic = now
        item = _WorkItem(batch, now)
        self.submitted_batches += 1
        self.submitted_requests += batch.size
        self._open[item.item_id] = item
        self._enqueue(item)
        # Reactive fast-path: Knative's activator pokes the autoscaler on
        # traffic from zero; model that by an immediate scale check.
        if self._n_ready == 0 and self._n_provisioned == 0:
            self._scale_to(max(1, self.config.min_scale), now)
        self._try_assign(now)

    @property
    def billable_count(self) -> int:
        """Containers currently billed (provisioned or draining)."""
        return self._billable_count()

    def ready_count(self, now: float) -> int:
        """Containers ready to accept work at ``now``."""
        return self._ready_count(now)

    @property
    def queued_batches(self) -> int:
        """Live (not-yet-done) work items waiting in the platform queue."""
        return self._queued_count

    # --------------------------------------------------------------- ledger
    def _enqueue(self, item: _WorkItem, front: bool = False) -> None:
        """Put ``item`` (back) into the pending queue exactly once."""
        if item.queued or item.done:
            return
        item.queued = True
        self._queued_count += 1
        if front:
            self.pending.appendleft(item)
        else:
            self.pending.append(item)

    def _mark_dequeued(self, item: _WorkItem) -> None:
        """Logically remove ``item`` from pending (deque entry goes stale)."""
        if item.queued:
            item.queued = False
            self._queued_count -= 1

    def _resolve_attempt(self, a: _Attempt, now: float,
                         container_dead: bool = False) -> None:
        """Take one attempt out of the ledger, freeing its slot.

        ``container_dead`` skips per-slot bookkeeping when the whole
        container just crashed (its occupancy is zeroed wholesale).
        """
        if a.resolved:
            return
        a.resolved = True
        self._live_attempts -= 1
        a.item.live.remove(a)
        c = a.container
        if a in c.attempts:
            c.attempts.remove(a)
        if not container_dead and not c.terminated:
            c.inflight = max(0, c.inflight - 1)
            if c.draining:
                if c.inflight == 0:
                    self._mark_terminated(c, now)
            else:
                self._heap_push(c)  # a slot just freed

    # ------------------------------------------------------------- internals
    def _provisioned_count(self) -> int:
        return self._n_provisioned

    def _billable_count(self) -> int:
        return self._n_billable

    def _ready_count(self, now: float) -> int:
        return self._n_ready

    def _heap_push(self, c: _Container) -> None:
        """Offer ``c`` to the free-heap (cid order == creation order, so
        assignment prefers the oldest free container, as the old full scan
        did). Entries are lazily invalidated on pop."""
        if not c.in_heap and not c.terminated and not c.draining:
            c.in_heap = True
            heapq.heappush(self._free_heap, (c.cid, c))

    def _mark_terminated(self, c: _Container, now: float) -> None:
        """Centralized terminate transition: billing + counters."""
        self._accrue_billing(now)
        c.terminated = True
        self._n_billable -= 1
        if not c.draining:
            self._n_provisioned -= 1
        if c.counted_ready:
            c.counted_ready = False
            self._n_ready -= 1
        self._billing_last_n = self._n_billable

    def _concurrency(self) -> float:
        # Ledger-derived: live attempts + queued live items. Items that
        # completed while a stale copy sat in ``pending`` are excluded, so
        # crash/hedge churn cannot inflate the autoscaler signal.
        return float(self._live_attempts + self._queued_count)

    def _accrue_conc(self, now: float) -> None:
        """Advance the time-weighted concurrency integral to ``now``."""
        if now > self._conc_t:
            self._conc_integral += self._concurrency() * (now - self._conc_t)
            self._conc_t = now

    def _accrue_billing(self, now: float) -> None:
        self.container_seconds += self._billing_last_n * (now - self._billing_last_t)
        self._billing_last_t = now
        self._billing_last_n = self._billable_count()

    def _start_container(self, now: float, cold: bool = True) -> None:
        self._accrue_billing(now)
        delay = self.config.cold_start if cold else 0.0
        c = _Container(ready_at=now + delay)
        self.containers.append(c)
        self._n_provisioned += 1
        self._n_billable += 1
        if cold:
            self.cold_starts += 1
            self.events.push(c.ready_at, partial(self._on_container_ready, c))
        else:
            c.counted_ready = True
            self._n_ready += 1
            self._heap_push(c)
        self._billing_last_n = self._n_billable
        if self._n_billable > self.peak_containers:
            self.peak_containers = self._n_billable

    def _on_container_ready(self, c: _Container, now: float) -> None:
        if c.terminated:
            return  # scaled down (or crashed) before it ever became ready
        if not c.draining:
            c.counted_ready = True
            self._n_ready += 1
            self._heap_push(c)
        self._try_assign(now)

    def _terminate(self, c: _Container, now: float) -> None:
        if c.inflight > 0:
            # drains, then terminates when its last live attempt resolves
            self._accrue_billing(now)
            c.draining = True
            self._n_provisioned -= 1
            if c.counted_ready:
                c.counted_ready = False
                self._n_ready -= 1
            self._billing_last_n = self._n_billable
        else:
            self._mark_terminated(c, now)

    def _try_assign(self, now: float) -> None:
        self._accrue_conc(now)
        if self._queued_count == 0:
            return
        conc = self.config.container_concurrency
        heap = self._free_heap
        pending = self.pending
        # Containers that still have a free slot but whose slot no queued
        # item may use (anti-affinity): parked aside, restored afterwards.
        blocked: List[Tuple[int, _Container]] = []
        while self._queued_count > 0 and heap:
            cid_c = heap[0]
            c = cid_c[1]
            if c.terminated or c.draining or c.inflight >= conc:
                heapq.heappop(heap)  # stale entry
                c.in_heap = False
                continue
            deferred: List[_WorkItem] = []
            item = None
            while pending:
                it = pending.popleft()
                if not it.queued or it.done:
                    continue  # stale deque entry; already resolved elsewhere
                if any(a.container is c for a in it.live):
                    # anti-affinity: a hedge/retry must not land next to its
                    # own live sibling — it would share the sibling's fate
                    deferred.append(it)
                    continue
                item = it
                break
            for d in reversed(deferred):
                pending.appendleft(d)
            if item is None:
                heapq.heappop(heap)  # free, but unusable for this queue
                blocked.append(cid_c)
                continue
            self._mark_dequeued(item)
            self._execute(c, item, now)
        for entry in blocked:
            heapq.heappush(heap, entry)

    def _execute(self, c: _Container, item: _WorkItem, now: float) -> None:
        cfg = self.config
        c.inflight += 1
        item.attempts += 1
        service = self.latency.sample_batch(item.batch, self.rng)
        if cfg.ps_slowdown > 0 and c.inflight > 1:
            service *= 1.0 + cfg.ps_slowdown * (c.inflight - 1)
        if cfg.straggler_prob > 0 and self.fault_rng.random() < cfg.straggler_prob:
            service *= cfg.straggler_mult
        fail = (cfg.failure_prob_per_batch > 0
                and self.fault_rng.random() < cfg.failure_prob_per_batch)
        # Preemption draw is guarded so zero-prob configs consume no extra
        # randomness (byte-identity with pre-preemption runs); a crash on
        # the same attempt wins — the container cannot die twice.
        preempt = (not fail and cfg.preempt_prob_per_batch > 0
                   and self.fault_rng.random() < cfg.preempt_prob_per_batch)
        a = _Attempt(item, c, start=now, eta=now + service)
        item.live.append(a)
        c.attempts.append(a)
        self._live_attempts += 1
        if self.tracer is not None:
            self.tracer.emit(now, "attempt", item.batch.endpoint,
                             batch=item.batch.trace_id,
                             size=item.batch.size, value=service,
                             detail=f"try{item.attempts}")
        if fail:
            # crash at a uniform point during service; every live attempt
            # on the container is requeued in _crash
            a.eta = now + service * float(self.fault_rng.random())
            self.events.push(a.eta, partial(self._crash, a))
        elif preempt:
            # spot reclaim at a uniform point during service; same requeue
            # semantics as a crash, separate accounting (_preempt)
            a.eta = now + service * float(self.fault_rng.random())
            self.events.push(a.eta, partial(self._preempt, a))
        else:
            self.events.push(a.eta, partial(self._complete, a))
            if cfg.hedge_factor > 0 and item.hedges < cfg.max_hedges:
                est = self.latency.mean_batch(item.batch)
                self.events.push(
                    now + cfg.hedge_factor * est,
                    partial(self._maybe_hedge, a),
                )

    def _maybe_hedge(self, a: _Attempt, now: float) -> None:
        item = a.item
        if item.done or a.resolved or item.queued:
            return  # finished, superseded, or already awaiting re-dispatch
        if item.hedges >= self.config.max_hedges:
            return
        # straggler suspected: re-dispatch a duplicate; first finisher wins.
        # _try_assign places it anti-affine to the straggling attempt.
        self._accrue_conc(now)  # charge the pre-hedge interval at the old level
        item.hedges += 1
        self.hedged_dispatches += 1
        if self.tracer is not None:
            self.tracer.emit(now, "hedge", item.batch.endpoint,
                             batch=item.batch.trace_id,
                             size=item.batch.size)
        self._enqueue(item, front=True)
        self._try_assign(now)

    def _crash(self, a: _Attempt, now: float) -> None:
        if a.resolved:
            return  # attempt was cancelled/completed before the fault hit
        if a.container.terminated:
            return
        self._accrue_conc(now)
        self.failed_attempts += 1
        self._reclaim_container(a, now, detail="crash")

    def _preempt(self, a: _Attempt, now: float) -> None:
        """Spot-style reclaim: the platform takes the container back
        mid-batch. Same ledger path as a crash (every co-resident attempt
        requeued, nothing lost), but billed to the preemption counters so
        chaos reports can tell lost capacity from reclaimed capacity."""
        if a.resolved:
            return  # attempt was cancelled/completed before the reclaim
        if a.container.terminated:
            return
        self._accrue_conc(now)
        self.preemptions += 1
        self.preempted_attempts += self._reclaim_container(
            a, now, detail="preempt")

    def _reclaim_container(self, a: _Attempt, now: float,
                           detail: str) -> int:
        """Terminate ``a``'s container mid-service (crash or preemption),
        requeueing every live attempt through the ledger. Returns the
        number of attempts the reclaim cancelled."""
        c = a.container
        if self.tracer is not None:
            self.tracer.emit(now, "fault", a.item.batch.endpoint,
                             batch=a.item.batch.trace_id,
                             size=a.item.batch.size, detail=detail)
        self._mark_terminated(c, now)
        # resolve EVERY live attempt on the dead container — co-resident
        # batches die with it and must be requeued, not leaked
        victims = list(c.attempts)
        for v in victims:
            self._resolve_attempt(v, now, container_dead=True)
        c.inflight = 0
        for v in reversed(victims):  # appendleft keeps oldest-first order
            it = v.item
            if not it.done and not it.queued and not it.live:
                self.requeued_batches += 1
                if self.tracer is not None:
                    self.tracer.emit(now, "retry", it.batch.endpoint,
                                     batch=it.batch.trace_id,
                                     size=it.batch.size, detail="requeue")
                self._enqueue(it, front=True)  # at-least-once re-dispatch
        self._try_assign(now)
        return len(victims)

    def _complete(self, a: _Attempt, now: float) -> None:
        if a.resolved:
            return  # sibling won or container crashed under this attempt
        item = a.item
        self._accrue_conc(now)
        self._resolve_attempt(a, now)
        if item.done:
            # unreachable by construction (winning completion resolves all
            # siblings); counted defensively so a regression is loud
            self.duplicate_completions += 1
        else:
            item.done = True
            # first finisher wins: cancel sibling attempts immediately so
            # their slots free now, not when their stale timers fire
            for sib in list(item.live):
                self._resolve_attempt(sib, now)
                self.cancelled_attempts += 1
            self._mark_dequeued(item)
            self._open.pop(item.item_id, None)
            self.completed_batches += 1
            self.completed_requests += item.batch.size
            item.batch.attempts = item.attempts
            if self.tracer is not None:
                self.tracer.emit(now, "completed", item.batch.endpoint,
                                 batch=item.batch.trace_id,
                                 size=item.batch.size,
                                 value=now - item.submit_time)
            self.on_batch_done(item.batch, now - item.submit_time, now)
        self._try_assign(now)

    # --------------------------------------------------------------- metrics
    def register_metrics(self, registry, prefix: str = "platform") -> None:
        """Bind the platform's lifetime ledger into a MetricsRegistry."""
        b = registry.bind
        b(f"{prefix}.submitted_batches", lambda: self.submitted_batches)
        b(f"{prefix}.submitted_requests", lambda: self.submitted_requests)
        b(f"{prefix}.completed_batches", lambda: self.completed_batches)
        b(f"{prefix}.completed_requests", lambda: self.completed_requests)
        b(f"{prefix}.failed_attempts", lambda: self.failed_attempts)
        b(f"{prefix}.preemptions", lambda: self.preemptions)
        b(f"{prefix}.preempted_attempts", lambda: self.preempted_attempts)
        b(f"{prefix}.requeued_batches", lambda: self.requeued_batches)
        b(f"{prefix}.hedged_dispatches", lambda: self.hedged_dispatches)
        b(f"{prefix}.cancelled_attempts", lambda: self.cancelled_attempts)
        b(f"{prefix}.duplicate_completions",
          lambda: self.duplicate_completions)
        b(f"{prefix}.cold_starts", lambda: self.cold_starts)
        b(f"{prefix}.peak_containers", lambda: self.peak_containers)

    # --------------------------------------------------------- conservation
    def conservation(self) -> dict:
        """Point-in-time conservation ledger.

        Invariants (asserted by :meth:`assert_conserved`): every submitted
        batch is either completed, queued, or in flight (``lost == 0``) and
        no batch ever completes twice (``duplicate_completions == 0``).
        """
        queued = sum(1 for it in self._open.values() if it.queued)
        inflight = sum(
            1 for it in self._open.values() if not it.queued and it.live
        )
        lost = sum(
            1 for it in self._open.values() if not it.queued and not it.live
        )
        return {
            "submitted_batches": self.submitted_batches,
            "submitted_requests": self.submitted_requests,
            "completed_batches": self.completed_batches,
            "completed_requests": self.completed_requests,
            "queued_batches": queued,
            "inflight_batches": inflight,
            "outstanding_batches": len(self._open),
            "lost_batches": lost,
            "duplicate_completions": self.duplicate_completions,
            "requeued_batches": self.requeued_batches,
            "hedged_dispatches": self.hedged_dispatches,
            "cancelled_attempts": self.cancelled_attempts,
            "failed_attempts": self.failed_attempts,
            "preemptions": self.preemptions,
            "preempted_attempts": self.preempted_attempts,
            "cold_starts": self.cold_starts,
        }

    def assert_conserved(self, require_drained: bool = False) -> dict:
        """Raise ``AssertionError`` if any conservation invariant is broken.

        ``require_drained`` additionally demands that nothing is left
        outstanding — i.e. every submitted request completed exactly once
        (the end-of-run form of the invariant).
        """
        c = self.conservation()

        def trip(reason: str) -> AssertionError:
            # flight-recorder postmortem fires BEFORE the raise so the
            # ring survives even when the caller swallows the error
            if self.recorder is not None:
                self.recorder.dump(f"conservation-{reason}",
                                   now=self._conc_t, extra=c)
            return AssertionError(f"{reason}: {c}")

        if c["lost_batches"] != 0:
            raise trip("lost batches")
        if c["duplicate_completions"] != 0:
            raise trip("duplicate completions")
        accounted = (
            c["completed_batches"] + c["queued_batches"] + c["inflight_batches"]
        )
        if accounted != c["submitted_batches"]:
            raise trip("conservation imbalance")
        if require_drained:
            if c["outstanding_batches"] != 0:
                raise trip("undrained work at end of run")
            if c["completed_requests"] != c["submitted_requests"]:
                raise trip("request count mismatch")
        return c

    # ------------------------------------------------------------ autoscaler
    def _metric_tick(self, now: float) -> None:
        self._accrue_conc(now)
        # prune terminated containers — _scale_to and the crash path still
        # walk this list; without pruning long churny runs leak memory
        if len(self.containers) > 2 * max(self._n_provisioned, 1):
            self.containers = [c for c in self.containers if not c.terminated]
        self._conc_samples.append((now, self._conc_integral))
        cutoff = now - self.config.stable_window - 2 * self.config.metric_tick
        while self._conc_samples and self._conc_samples[0][0] < cutoff:
            self._conc_samples.popleft()
        self.timeline.append(
            (now, self._n_billable, self._n_ready, self._queued_count)
        )
        self.events.push(now + self.config.metric_tick, self._metric_tick)

    def _window_avg(self, now: float, window: float) -> float:
        """Time-weighted average concurrency over the trailing window."""
        if not self._conc_samples:
            return 0.0
        t_end, i_end = self._conc_samples[-1]
        target = now - window
        start: Optional[Tuple[float, float]] = None
        for (t, i) in self._conc_samples:
            if t >= target:
                start = (t, i)
                break
        if start is None:
            # every sample predates the window: the buffer only holds stale
            # history, so report the instantaneous signal instead of the
            # average over the whole (out-of-window) buffer
            return self._concurrency()
        t_start, i_start = start
        if t_end <= t_start:
            return self._concurrency()
        return (i_end - i_start) / (t_end - t_start)

    def _autoscale_tick(self, now: float) -> None:
        cfg = self.config
        per_pod = cfg.container_concurrency * cfg.target_utilization
        stable = self._window_avg(now, cfg.stable_window)
        panic = self._window_avg(now, cfg.panic_window)
        current = self._provisioned_count()

        desired_stable = math.ceil(stable / per_pod) if stable > 0 else 0
        desired_panic = math.ceil(panic / per_pod) if panic > 0 else 0

        if current > 0 and panic >= cfg.panic_threshold * per_pod * current:
            self._panic_until = now + cfg.stable_window
        in_panic = now <= self._panic_until

        desired = max(desired_stable, desired_panic) if in_panic else desired_stable
        if in_panic:
            desired = max(desired, current)  # no scale-down during panic
        # scale-to-zero only after the grace period with no traffic
        if desired == 0 and (now - self._last_traffic) < cfg.scale_to_zero_grace:
            desired = max(1, cfg.min_scale) if self._last_traffic > 0 else cfg.min_scale
        # Knative rate limits (per autoscale tick)
        effective = max(current, 1)
        desired = min(desired, math.ceil(effective * cfg.max_scale_up_rate))
        desired = max(desired, math.floor(effective / cfg.max_scale_down_rate))
        desired = max(cfg.min_scale, min(cfg.max_scale, desired))
        if desired != current:
            self._scale_to(desired, now)
        self.events.push(now + cfg.autoscale_tick, self._autoscale_tick)

    def _scale_to(self, desired: int, now: float) -> None:
        current = self._provisioned_count()
        if desired > current:
            for _ in range(desired - current):
                self._start_container(now)
        elif desired < current:
            # terminate idle containers first, newest first
            victims = sorted(
                (c for c in self.containers if not c.terminated and not c.draining),
                key=lambda c: (c.inflight > 0, -c.ready_at),
            )
            for c in victims[: current - desired]:
                self._terminate(c, now)
        self._try_assign(now)

    # ---------------------------------------------------------------- report
    def reset_billing(self, now: float) -> None:
        """Zero the billing integral (end-of-warmup barrier)."""
        self._accrue_billing(now)
        self.container_seconds = 0.0
        self._billing_last_t = now
        self.peak_containers = self._billable_count()
        self.cold_starts = 0

    def finalize(self, now: float) -> None:
        self._accrue_billing(now)

    @property
    def cost_integral(self) -> float:
        """Billable container-seconds accrued since the last billing reset.

        The platform's cost metric is this *integral* of billable
        (provisioned or draining) containers over time — not a container
        count. :class:`~repro.serverless.tiers.TieredPlatform` applies
        per-tier cost weights on top; the paper's "number of containers"
        figure is this integral / billing window (:meth:`avg_containers`).
        """
        return self.container_seconds

    def avg_containers(self, duration: float) -> float:
        return self.container_seconds / duration if duration > 0 else 0.0
