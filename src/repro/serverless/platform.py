"""Simulated serverless platform (Knative-shaped) for experiments.

Models the pieces of Knative that the paper's evaluation depends on:

* **Containers** with a concurrency limit (``containerConcurrency``); a
  batched request occupies one concurrency slot for its service time (the
  ML serving containers in the paper process requests serially).
* **KPA autoscaler**: concurrency-based scaling with a stable window, a
  panic window, target utilization, scale-to-zero after a grace period and
  cold-start delay for new containers.
* **Activator queue**: requests (batches) that arrive when no slot is free
  queue platform-side; their queueing time is part of the upstream response
  time the proxy's monitor observes — exactly what MLProxy sees through its
  HTTP client.
* **Billing**: integral of provisioned containers over time; the paper's
  cost metric ("number of containers") is this integral / duration.
* **Fault injection** (beyond paper, required at production scale): random
  container crashes with at-least-once re-dispatch, straggler service
  times, and optional hedged duplicates for straggler mitigation.

The platform is clock-free like the proxy: it schedules itself on the
shared :class:`~repro.simulation.events.EventQueue`.
"""
from __future__ import annotations

import collections
import dataclasses
import itertools
import math
from typing import Callable, Deque, Dict, List, Optional, Tuple

import numpy as np

from repro.core.request import Batch
from repro.serverless.latency import LatencyModel
from repro.simulation.events import EventQueue


@dataclasses.dataclass(frozen=True)
class PlatformConfig:
    container_concurrency: int = 1
    target_utilization: float = 0.7
    autoscale_tick: float = 2.0
    metric_tick: float = 1.0
    stable_window: float = 60.0
    panic_window: float = 6.0
    panic_threshold: float = 2.0
    scale_to_zero_grace: float = 30.0
    cold_start: float = 4.0
    min_scale: int = 0
    max_scale: int = 1000
    initial_scale: int = 0
    # Knative rate limits: desired ≤ up_rate × current per tick, and
    # desired ≥ current / down_rate per tick.
    max_scale_up_rate: float = 10.0
    max_scale_down_rate: float = 2.0
    # Processor-sharing slowdown: with k batches co-resident on one
    # container, each takes ×(1 + ps_slowdown·(k−1)) longer (CPU-bound ML
    # containers serialize; 1.0 ≈ perfect processor sharing).
    ps_slowdown: float = 1.0
    # Fault injection / mitigation (beyond paper)
    failure_prob_per_batch: float = 0.0
    straggler_prob: float = 0.0
    straggler_mult: float = 5.0
    hedge_factor: float = 0.0  # >0 enables hedged re-dispatch at f×E[s]


class _Container:
    _ids = itertools.count()

    def __init__(self, ready_at: float) -> None:
        self.cid = next(_Container._ids)
        self.ready_at = ready_at
        self.terminated = False
        self.draining = False  # finish in-flight work then terminate
        self.inflight: int = 0

    def is_ready(self, now: float) -> bool:
        return not self.terminated and now >= self.ready_at

    def available_slots(self, now: float, concurrency: int) -> int:
        if not self.is_ready(now) or self.draining:
            return 0
        return max(0, concurrency - self.inflight)


class _WorkItem:
    _ids = itertools.count()

    def __init__(self, batch: Batch, submit_time: float) -> None:
        self.item_id = next(_WorkItem._ids)
        self.batch = batch
        self.submit_time = submit_time
        self.done = False
        self.attempts = 0


class ServerlessPlatform:
    """Discrete-event Knative-like platform fed by a batching policy."""

    def __init__(
        self,
        config: PlatformConfig,
        latency_model: LatencyModel,
        events: EventQueue,
        rng: np.random.Generator,
        on_batch_done: Callable[[Batch, float, float], None],
    ) -> None:
        """``on_batch_done(batch, upstream_latency, now)`` fires once per batch."""
        self.config = config
        self.latency = latency_model
        self.events = events
        self.rng = rng
        self.on_batch_done = on_batch_done

        self.containers: List[_Container] = []
        self.pending: Deque[_WorkItem] = collections.deque()
        # time-weighted concurrency (Knative's queue-proxy reports average
        # concurrency over each reporting period, not point samples —
        # point-sampling misses sub-second batches and flaps the panic mode)
        self._conc_samples: Deque[Tuple[float, float]] = collections.deque()
        self._conc_integral = 0.0
        self._conc_t = 0.0
        self._last_traffic: float = 0.0
        self._panic_until: float = -1.0
        self._started = False

        # billing + metrics
        self.container_seconds = 0.0
        self._billing_last_t = 0.0
        self._billing_last_n = 0
        self.completed_batches = 0
        self.failed_attempts = 0
        self.hedged_dispatches = 0
        self.cold_starts = 0
        self.peak_containers = 0
        self.timeline: List[Tuple[float, int, int, int]] = []  # (t, provisioned, ready, queued)

        for _ in range(max(config.min_scale, config.initial_scale)):
            self._start_container(0.0, cold=False)

    # ------------------------------------------------------------------ api
    def start(self, now: float) -> None:
        """Begin autoscaler + metric ticking."""
        if self._started:
            return
        self._started = True
        self._billing_last_t = now
        self.events.push(now + self.config.metric_tick, self._metric_tick)
        self.events.push(now + self.config.autoscale_tick, self._autoscale_tick)

    def submit(self, batch: Batch, now: float) -> None:
        """One upstream HTTP request carrying ``batch`` (the proxy's view)."""
        self.start(now)
        self._accrue_conc(now)
        self._last_traffic = now
        item = _WorkItem(batch, now)
        self.pending.append(item)
        # Reactive fast-path: Knative's activator pokes the autoscaler on
        # traffic from zero; model that by an immediate scale check.
        if self._ready_count(now) == 0 and self._provisioned_count() == 0:
            self._scale_to(max(1, self.config.min_scale), now)
        self._try_assign(now)

    @property
    def billable_count(self) -> int:
        """Containers currently billed (provisioned or draining)."""
        return self._billable_count()

    def ready_count(self, now: float) -> int:
        """Containers ready to accept work at ``now``."""
        return self._ready_count(now)

    # ------------------------------------------------------------- internals
    def _provisioned_count(self) -> int:
        return sum(1 for c in self.containers if not c.terminated and not c.draining)

    def _billable_count(self) -> int:
        return sum(1 for c in self.containers if not c.terminated)

    def _ready_count(self, now: float) -> int:
        return sum(1 for c in self.containers if c.is_ready(now) and not c.draining)

    def _concurrency(self) -> float:
        inflight = sum(c.inflight for c in self.containers if not c.terminated)
        return float(inflight + len(self.pending))

    def _accrue_conc(self, now: float) -> None:
        """Advance the time-weighted concurrency integral to ``now``."""
        if now > self._conc_t:
            self._conc_integral += self._concurrency() * (now - self._conc_t)
            self._conc_t = now

    def _accrue_billing(self, now: float) -> None:
        self.container_seconds += self._billing_last_n * (now - self._billing_last_t)
        self._billing_last_t = now
        self._billing_last_n = self._billable_count()

    def _start_container(self, now: float, cold: bool = True) -> None:
        self._accrue_billing(now)
        delay = self.config.cold_start if cold else 0.0
        c = _Container(ready_at=now + delay)
        self.containers.append(c)
        if cold:
            self.cold_starts += 1
            self.events.push(c.ready_at, self._on_container_ready)
        self._billing_last_n = self._billable_count()
        self.peak_containers = max(self.peak_containers, self._billable_count())

    def _on_container_ready(self, now: float) -> None:
        self._try_assign(now)

    def _terminate(self, c: _Container, now: float) -> None:
        self._accrue_billing(now)
        if c.inflight > 0:
            c.draining = True  # terminates in _complete
        else:
            c.terminated = True
        self._billing_last_n = self._billable_count()

    def _try_assign(self, now: float) -> None:
        self._accrue_conc(now)
        conc = self.config.container_concurrency
        for c in self.containers:
            if not self.pending:
                break
            slots = c.available_slots(now, conc)
            while slots > 0 and self.pending:
                item = self.pending.popleft()
                if item.done:
                    continue
                self._execute(c, item, now)
                slots -= 1

    def _execute(self, c: _Container, item: _WorkItem, now: float) -> None:
        cfg = self.config
        c.inflight += 1
        item.attempts += 1
        service = self.latency.sample_batch(item.batch, self.rng)
        if cfg.ps_slowdown > 0 and c.inflight > 1:
            service *= 1.0 + cfg.ps_slowdown * (c.inflight - 1)
        if cfg.straggler_prob > 0 and self.rng.random() < cfg.straggler_prob:
            service *= cfg.straggler_mult
        fail = cfg.failure_prob_per_batch > 0 and self.rng.random() < cfg.failure_prob_per_batch
        if fail:
            # crash at a uniform point during service; batch re-queued
            crash_after = service * float(self.rng.random())
            self.events.push(now + crash_after, lambda t, c=c, item=item: self._crash(c, item, t))
        else:
            self.events.push(now + service, lambda t, c=c, item=item: self._complete(c, item, t))
            if cfg.hedge_factor > 0:
                est = self.latency.mean_batch(item.batch)
                self.events.push(
                    now + cfg.hedge_factor * est,
                    lambda t, item=item: self._maybe_hedge(item, t),
                )

    def _maybe_hedge(self, item: _WorkItem, now: float) -> None:
        if item.done:
            return
        # straggler suspected: re-dispatch a duplicate; first finisher wins
        self.hedged_dispatches += 1
        self.pending.appendleft(item)
        self._try_assign(now)

    def _crash(self, c: _Container, item: _WorkItem, now: float) -> None:
        if c.terminated:
            return
        self._accrue_conc(now)
        self.failed_attempts += 1
        self._accrue_billing(now)
        c.terminated = True
        c.inflight = 0
        self._billing_last_n = self._billable_count()
        if not item.done:
            self.pending.appendleft(item)  # at-least-once re-dispatch
        self._try_assign(now)

    def _complete(self, c: _Container, item: _WorkItem, now: float) -> None:
        if c.terminated:
            return  # crashed while running; handled in _crash
        self._accrue_conc(now)
        c.inflight = max(0, c.inflight - 1)
        if c.draining and c.inflight == 0:
            self._accrue_billing(now)
            c.terminated = True
            self._billing_last_n = self._billable_count()
        if not item.done:
            item.done = True
            self.completed_batches += 1
            self.on_batch_done(item.batch, now - item.submit_time, now)
        self._try_assign(now)

    # ------------------------------------------------------------ autoscaler
    def _metric_tick(self, now: float) -> None:
        self._accrue_conc(now)
        # prune terminated containers — _try_assign scans this list on every
        # completion; without pruning long churny runs go quadratic
        if len(self.containers) > 4 * max(self._provisioned_count(), 1):
            self.containers = [c for c in self.containers if not c.terminated]
        self._conc_samples.append((now, self._conc_integral))
        cutoff = now - self.config.stable_window - 2 * self.config.metric_tick
        while self._conc_samples and self._conc_samples[0][0] < cutoff:
            self._conc_samples.popleft()
        self.timeline.append(
            (now, self._billable_count(), self._ready_count(now), len(self.pending))
        )
        self.events.push(now + self.config.metric_tick, self._metric_tick)

    def _window_avg(self, now: float, window: float) -> float:
        """Time-weighted average concurrency over the trailing window."""
        if not self._conc_samples:
            return 0.0
        t_end, i_end = self._conc_samples[-1]
        target = now - window
        t_start, i_start = self._conc_samples[0]
        for (t, i) in self._conc_samples:
            if t >= target:
                t_start, i_start = t, i
                break
        if t_end <= t_start:
            return self._concurrency()
        return (i_end - i_start) / (t_end - t_start)

    def _autoscale_tick(self, now: float) -> None:
        cfg = self.config
        per_pod = cfg.container_concurrency * cfg.target_utilization
        stable = self._window_avg(now, cfg.stable_window)
        panic = self._window_avg(now, cfg.panic_window)
        current = self._provisioned_count()

        desired_stable = math.ceil(stable / per_pod) if stable > 0 else 0
        desired_panic = math.ceil(panic / per_pod) if panic > 0 else 0

        if current > 0 and panic >= cfg.panic_threshold * per_pod * current:
            self._panic_until = now + cfg.stable_window
        in_panic = now <= self._panic_until

        desired = max(desired_stable, desired_panic) if in_panic else desired_stable
        if in_panic:
            desired = max(desired, current)  # no scale-down during panic
        # scale-to-zero only after the grace period with no traffic
        if desired == 0 and (now - self._last_traffic) < cfg.scale_to_zero_grace:
            desired = max(1, cfg.min_scale) if self._last_traffic > 0 else cfg.min_scale
        # Knative rate limits (per autoscale tick)
        effective = max(current, 1)
        desired = min(desired, math.ceil(effective * cfg.max_scale_up_rate))
        desired = max(desired, math.floor(effective / cfg.max_scale_down_rate))
        desired = max(cfg.min_scale, min(cfg.max_scale, desired))
        if desired != current:
            self._scale_to(desired, now)
        self.events.push(now + cfg.autoscale_tick, self._autoscale_tick)

    def _scale_to(self, desired: int, now: float) -> None:
        current = self._provisioned_count()
        if desired > current:
            for _ in range(desired - current):
                self._start_container(now)
        elif desired < current:
            # terminate idle containers first, newest first
            victims = sorted(
                (c for c in self.containers if not c.terminated and not c.draining),
                key=lambda c: (c.inflight > 0, -c.ready_at),
            )
            for c in victims[: current - desired]:
                self._terminate(c, now)
        self._try_assign(now)

    # ---------------------------------------------------------------- report
    def reset_billing(self, now: float) -> None:
        """Zero the billing integral (end-of-warmup barrier)."""
        self._accrue_billing(now)
        self.container_seconds = 0.0
        self._billing_last_t = now
        self.peak_containers = self._billable_count()
        self.cold_starts = 0

    def finalize(self, now: float) -> None:
        self._accrue_billing(now)

    def avg_containers(self, duration: float) -> float:
        return self.container_seconds / duration if duration > 0 else 0.0
