"""Upstream service-time models for the simulated serverless platform.

The paper's enabling observation (Figs. 3–4) is that ML service time grows
*sub-linearly* in batch size because per-request overhead (HTTP handling,
framework dispatch, Python) amortizes while the vectorized compute scales.
The affine model ``s(b) = a + c·b`` captures exactly that: relative response
time ``s(b)/s(1)`` grows slowly when ``a ≫ c`` and time-per-inference
``s(b)/b`` collapses toward ``c``.

Models:
  * :class:`AffineLatency` — ``a + c·b`` (primary; calibrated per workload).
  * :class:`PowerLawLatency` — ``base · b^γ`` with γ < 1.
  * :class:`LinearLatency` — ``base · b``: the paper's negative control
    ("linear baseline"); batching gives no benefit and MLProxy should not
    help (Fig 3/4 linear baseline, §4.3 limitations).
  * :class:`MeasuredLatency` — interpolates a measured (batch → seconds)
    table, e.g. produced by ``benchmarks/bench_batch_scaling.py`` running
    the real JAX workload models on this host.

All models multiply a lognormal noise term with configurable coefficient of
variation, and a queuing slowdown factor for co-scheduled work.
"""
from __future__ import annotations

import bisect
import dataclasses
import math
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np


class LatencyModel:
    """Protocol: deterministic mean + noisy sample, both in seconds."""

    name: str = "latency"

    def mean(self, batch_size: int) -> float:
        raise NotImplementedError

    def sample(self, batch_size: int, rng: np.random.Generator) -> float:
        s = self.mean(batch_size)
        cv = getattr(self, "noise_cv", 0.0)
        if cv <= 0:
            return s
        # lognormal with E=1, CV=cv
        sigma2 = math.log(1.0 + cv * cv)
        noise = rng.lognormal(mean=-sigma2 / 2.0, sigma=math.sqrt(sigma2))
        return s * noise

    # Batch-aware hooks: the platform calls these so multi-model latency
    # models (one shared container fleet serving several endpoints) can
    # route on the batch's endpoint stamp; the default ignores it.
    def mean_batch(self, batch) -> float:
        return self.mean(batch.effective_size)

    def sample_batch(self, batch, rng: np.random.Generator) -> float:
        return self.sample(batch.effective_size, rng)

    def percentile(self, batch_size: int, q: float) -> float:
        """Analytic percentile of the noisy model (for oracle baselines)."""
        s = self.mean(batch_size)
        cv = getattr(self, "noise_cv", 0.0)
        if cv <= 0:
            return s
        sigma2 = math.log(1.0 + cv * cv)
        from statistics import NormalDist

        z = NormalDist().inv_cdf(q / 100.0)
        return s * math.exp(-sigma2 / 2.0 + math.sqrt(sigma2) * z)


@dataclasses.dataclass
class AffineLatency(LatencyModel):
    """s(b) = a + c·b. ``a`` is the per-request-independent overhead."""

    a: float
    c: float
    noise_cv: float = 0.1
    name: str = "affine"

    def mean(self, batch_size: int) -> float:
        return self.a + self.c * batch_size

    @classmethod
    def fit(cls, points: Sequence[Tuple[int, float]], *,
            noise_cv: float = 0.0, name: str = "affine-fit") -> "AffineLatency":
        """Least-squares fit of ``s(b) = a + c·b`` to (batch, seconds) points.

        The calibration bridge (``repro.runtime.calibrate``) uses this to
        turn measured per-bucket batch latencies — from a live runtime run
        or ``bench_batch_scaling.py`` output — into simulator parameters.
        Both coefficients are clamped non-negative (a negative overhead or
        per-item cost is always a measurement artifact).
        """
        pts = [(float(b), float(s)) for b, s in points]
        if not pts:
            raise ValueError("AffineLatency.fit needs at least one point")
        if len(pts) == 1:
            return cls(a=max(0.0, pts[0][1]), c=0.0,
                       noise_cv=noise_cv, name=name)
        xs = np.asarray([b for b, _ in pts])
        ys = np.asarray([s for _, s in pts])
        c, a = np.polyfit(xs, ys, 1)
        return cls(a=max(0.0, float(a)), c=max(0.0, float(c)),
                   noise_cv=noise_cv, name=name)


@dataclasses.dataclass
class PowerLawLatency(LatencyModel):
    """s(b) = base · b^gamma, gamma ∈ (0, 1]."""

    base: float
    gamma: float
    noise_cv: float = 0.1
    name: str = "powerlaw"

    def mean(self, batch_size: int) -> float:
        return self.base * batch_size**self.gamma


@dataclasses.dataclass
class LinearLatency(LatencyModel):
    """s(b) = base · b — no batching benefit (negative control)."""

    base: float
    noise_cv: float = 0.1
    name: str = "linear"

    def mean(self, batch_size: int) -> float:
        return self.base * batch_size


@dataclasses.dataclass
class MeasuredLatency(LatencyModel):
    """Piecewise-linear interpolation over measured (batch_size, seconds)."""

    points: Sequence[Tuple[int, float]]
    noise_cv: float = 0.1
    name: str = "measured"

    def __post_init__(self) -> None:
        pts = sorted((int(b), float(s)) for b, s in self.points)
        if not pts:
            raise ValueError("MeasuredLatency needs at least one point")
        self._bs = [b for b, _ in pts]
        self._s = [s for _, s in pts]

    def mean(self, batch_size: int) -> float:
        xs, ys = self._bs, self._s
        if batch_size <= xs[0]:
            return ys[0]
        if batch_size >= xs[-1]:
            # extrapolate with the last segment's slope (conservative)
            if len(xs) >= 2:
                slope = (ys[-1] - ys[-2]) / (xs[-1] - xs[-2])
                return ys[-1] + slope * (batch_size - xs[-1])
            return ys[-1]
        i = bisect.bisect_right(xs, batch_size)
        x0, x1 = xs[i - 1], xs[i]
        y0, y1 = ys[i - 1], ys[i]
        t = (batch_size - x0) / (x1 - x0)
        return y0 + t * (y1 - y0)

    @classmethod
    def from_samples(cls, samples: Dict[int, Sequence[float]], *,
                     noise_cv: Optional[float] = None,
                     name: str = "measured") -> "MeasuredLatency":
        """Build from raw per-bucket latency samples (bucket → seconds list).

        Each bucket's point is the sample mean; when ``noise_cv`` is None
        it is estimated as the pooled coefficient of variation across
        buckets (0.0 when every bucket has a single sample).
        """
        pts = []
        cvs = []
        for b, vals in sorted(samples.items()):
            vals = [float(v) for v in vals]
            if not vals:
                continue
            m = sum(vals) / len(vals)
            pts.append((int(b), m))
            if len(vals) >= 2 and m > 0:
                var = sum((v - m) ** 2 for v in vals) / (len(vals) - 1)
                cvs.append(math.sqrt(var) / m)
        if not pts:
            raise ValueError("MeasuredLatency.from_samples got no samples")
        if noise_cv is None:
            noise_cv = sum(cvs) / len(cvs) if cvs else 0.0
        return cls(points=pts, noise_cv=noise_cv, name=name)


@dataclasses.dataclass
class ScaledLatency(LatencyModel):
    """Wrap another model, scaling every latency by a constant factor.

    The fleet-tier layer uses this for cheap-slow / expensive-fast tiers
    that share one calibrated workload curve: scaling the *output* (not
    re-parameterizing) keeps the wrapped model's RNG draw count identical,
    so a tiered run stays comparable draw-for-draw with its base run.
    """

    base: LatencyModel
    scale: float = 1.0
    name: str = "scaled"
    noise_cv: float = 0.0  # the wrapped model carries its own noise

    def mean(self, batch_size: int) -> float:
        return self.scale * self.base.mean(batch_size)

    def sample(self, batch_size: int, rng: np.random.Generator) -> float:
        return self.scale * self.base.sample(batch_size, rng)

    def mean_batch(self, batch) -> float:
        return self.scale * self.base.mean_batch(batch)

    def sample_batch(self, batch, rng: np.random.Generator) -> float:
        return self.scale * self.base.sample_batch(batch, rng)

    def percentile(self, batch_size: int, q: float) -> float:
        return self.scale * self.base.percentile(batch_size, q)


class EndpointRoutedLatency(LatencyModel):
    """Multi-model service times for a *shared* container fleet.

    Maps each batch's ``endpoint`` stamp (set by the
    :class:`~repro.core.frontend.ProxyFrontend`) to that endpoint's own
    latency model — one Knative service hosting several models. Size-only
    queries (``mean``/``sample``) fall back to the slowest member model,
    which keeps hedging and capacity estimates conservative.

    Keys are either plain endpoint names or ``(endpoint, tier)`` tuples.
    Lookup order for a batch stamped ``(endpoint=e, tier=t)``:

    1. ``(e, t)`` — tier-specific curve for this endpoint,
    2. ``e`` — the endpoint's tier-agnostic curve,

    and a ``KeyError`` naming both probes if neither is registered. A
    batch with no tier stamp skips step 1, so pre-tier configurations
    resolve exactly as before.
    """

    name = "endpoint-routed"
    noise_cv = 0.0  # member models carry their own noise

    def __init__(self, models: Dict[object, LatencyModel]) -> None:
        if not models:
            raise ValueError("EndpointRoutedLatency needs at least one model")
        self.models = dict(models)

    def _model_for(self, batch) -> LatencyModel:
        if batch.endpoint is None:
            raise KeyError("batch has no endpoint stamp; route it through a "
                           "ProxyFrontend before a shared platform")
        tier = getattr(batch, "tier", None)
        if tier is not None:
            m = self.models.get((batch.endpoint, tier))
            if m is not None:
                return m
        try:
            return self.models[batch.endpoint]
        except KeyError:
            probed = ([f"({batch.endpoint!r}, {tier!r})"] if tier is not None
                      else []) + [repr(batch.endpoint)]
            raise KeyError(
                f"no latency model for {' then '.join(probed)}; "
                f"registered: {sorted(map(repr, self.models))}") from None

    def mean(self, batch_size: int) -> float:
        return max(m.mean(batch_size) for m in self.models.values())

    def sample(self, batch_size: int, rng: np.random.Generator) -> float:
        worst = max(self.models.values(), key=lambda m: m.mean(batch_size))
        return worst.sample(batch_size, rng)

    def mean_batch(self, batch) -> float:
        return self._model_for(batch).mean(batch.effective_size)

    def sample_batch(self, batch, rng: np.random.Generator) -> float:
        return self._model_for(batch).sample(batch.effective_size, rng)


# --------------------------------------------------------------------------
# The paper's Table-2 workloads, calibrated so that s(1) equals the reported
# baseline response time (BRT) and the sub-linear shape matches Figs. 3–4
# (overhead-dominated: a ≈ 0.9·BRT). The "linear" entry is the negative
# control from the figures.
# --------------------------------------------------------------------------

PAPER_WORKLOADS: Dict[str, LatencyModel] = {
    # name: BRT (Table 2) split into overhead a + per-item c
    "sklearn-iris": AffineLatency(a=0.0065, c=0.0015, name="sklearn-iris"),
    "keras-toxic": AffineLatency(a=0.034, c=0.006, name="keras-toxic"),
    "onnx-resnet50": AffineLatency(a=0.110, c=0.091, name="onnx-resnet50"),
    "pytorch-fashion-mnist": AffineLatency(a=0.121, c=0.004, name="pytorch-fashion-mnist"),
    "tfserving-mobilenet": AffineLatency(a=0.055, c=0.028, name="tfserving-mobilenet"),
    "tfserving-resnet": AffineLatency(a=0.115, c=0.089, name="tfserving-resnet"),
    # negative control — linear scaling, no batching benefit (paper §4.3)
    "linear-control": LinearLatency(base=0.050, name="linear-control"),
}


def get_workload(name: str) -> LatencyModel:
    try:
        return PAPER_WORKLOADS[name]
    except KeyError:
        raise KeyError(
            f"unknown workload {name!r}; available: {sorted(PAPER_WORKLOADS)}"
        ) from None
