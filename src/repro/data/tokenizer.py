"""Byte-level tokenizer stub (self-contained; no vocab downloads)."""
from __future__ import annotations

from typing import List


class ByteTokenizer:
    def __init__(self, vocab_size: int = 256) -> None:
        if vocab_size < 2:
            raise ValueError("vocab_size must be >= 2")
        self.vocab_size = vocab_size

    def encode(self, text: str) -> List[int]:
        return [b % self.vocab_size for b in text.encode("utf-8")]

    def decode(self, ids) -> str:
        return bytes(int(i) % 256 for i in ids).decode("utf-8", errors="replace")
