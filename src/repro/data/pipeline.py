"""Token data pipeline for the training examples.

Deterministic, restartable synthetic LM data (byte-level corpus rolled into
fixed-length windows) — self-contained (no downloads) while exercising the
real pipeline machinery: sharded batches, prefetch, checkpointable iterator
state (step counter → exact resume after preemption).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional, Tuple

import numpy as np

from repro.data.tokenizer import ByteTokenizer

_DEFAULT_TEXT = (
    "MLProxy is an adaptive reverse proxy supporting efficient machine "
    "learning serving on serverless platforms. Batching requests reduces "
    "the per-inference overhead; an SLA-aware controller keeps the tail "
    "latency within the service level objective while the AIMD optimizer "
    "grows the batch size whenever the platform has headroom. "
) * 512


@dataclasses.dataclass
class DataConfig:
    seq_len: int = 256
    global_batch: int = 8
    vocab_size: int = 256
    seed: int = 0
    text: Optional[str] = None


class TokenDataset:
    """Checkpointable synthetic LM dataset.

    ``state()``/``restore()`` capture the iterator position so a preempted
    training job resumes on the exact batch it would have seen.
    """

    def __init__(self, config: DataConfig) -> None:
        self.config = config
        tok = ByteTokenizer(vocab_size=config.vocab_size)
        corpus = tok.encode(config.text or _DEFAULT_TEXT)
        # roll a long corpus; wrap-around indexing makes it infinite
        self._corpus = np.asarray(corpus, dtype=np.int32)
        if len(self._corpus) < config.seq_len + 1:
            reps = (config.seq_len + 1) // max(len(self._corpus), 1) + 1
            self._corpus = np.tile(self._corpus, reps)
        self._step = 0
        self._rng = np.random.default_rng(config.seed)

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        return self

    def __next__(self) -> Dict[str, np.ndarray]:
        cfg = self.config
        n = len(self._corpus) - cfg.seq_len - 1
        # deterministic offsets derived from (seed, step) — restartable
        rng = np.random.default_rng((cfg.seed, self._step))
        starts = rng.integers(0, n, size=cfg.global_batch)
        idx = starts[:, None] + np.arange(cfg.seq_len + 1)[None, :]
        window = self._corpus[idx]
        self._step += 1
        return {
            "tokens": window[:, :-1].astype(np.int32),
            "labels": window[:, :-1].astype(np.int32),  # next-token via shift in loss
        }

    # ------------------------------------------------------ fault tolerance
    def state(self) -> dict:
        return {"step": self._step, "seed": self.config.seed}

    def restore(self, state: dict) -> None:
        if state["seed"] != self.config.seed:
            raise ValueError("restoring dataset with a different seed")
        self._step = int(state["step"])

    @property
    def step(self) -> int:
        return self._step
