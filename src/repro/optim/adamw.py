"""AdamW in pure JAX with configurable state dtype and global-norm clipping.

State dtype matters at scale: bf16 first/second moments halve optimizer
memory (340B-param training does not fit 256×16GB otherwise — see
DESIGN.md §4); f32 is the default for small models.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    learning_rate: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip_norm: Optional[float] = 1.0
    state_dtype: str = "float32"  # 'float32' | 'bfloat16'


class AdamWState(NamedTuple):
    step: jax.Array
    mu: Any
    nu: Any


def init_state(config: AdamWConfig, params: Any) -> AdamWState:
    dt = jnp.dtype(config.state_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt) if jnp.issubdtype(
        p.dtype, jnp.floating) else jnp.zeros(p.shape, p.dtype)
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        mu=jax.tree.map(zeros, params),
        nu=jax.tree.map(zeros, params),
    )


def global_norm(tree: Any) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def apply_updates(config: AdamWConfig, params: Any, grads: Any,
                  state: AdamWState, lr_scale: jax.Array | float = 1.0,
                  ) -> Tuple[Any, AdamWState, dict]:
    """One AdamW step. Returns (params, state, metrics)."""
    gnorm = global_norm(grads)
    if config.grad_clip_norm is not None:
        scale = jnp.minimum(1.0, config.grad_clip_norm / jnp.maximum(gnorm, 1e-9))
        grads = jax.tree.map(lambda g: g * scale.astype(g.dtype), grads)
    step = state.step + 1
    b1, b2 = config.b1, config.b2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)
    lr = config.learning_rate * lr_scale

    def upd(p, g, m, n):
        if not jnp.issubdtype(p.dtype, jnp.floating):
            return p, m, n
        gf = g.astype(jnp.float32)
        mf = m.astype(jnp.float32) * b1 + gf * (1 - b1)
        nf = n.astype(jnp.float32) * b2 + jnp.square(gf) * (1 - b2)
        update = (mf / bc1) / (jnp.sqrt(nf / bc2) + config.eps)
        update = update + config.weight_decay * p.astype(jnp.float32)
        p_new = p.astype(jnp.float32) - lr * update
        return p_new.astype(p.dtype), mf.astype(m.dtype), nf.astype(n.dtype)

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state.mu)
    flat_n = jax.tree.leaves(state.nu)
    out = [upd(p, g, m, n) for p, g, m, n in zip(flat_p, flat_g, flat_m, flat_n)]
    new_p = jax.tree.unflatten(tdef, [o[0] for o in out])
    new_m = jax.tree.unflatten(tdef, [o[1] for o in out])
    new_n = jax.tree.unflatten(tdef, [o[2] for o in out])
    return new_p, AdamWState(step, new_m, new_n), {"grad_norm": gnorm}


# -------------------------------------------------------------- LR schedules
def cosine_schedule(step: jax.Array, *, warmup: int, total: int,
                    min_frac: float = 0.1) -> jax.Array:
    """Linear warmup then cosine decay to ``min_frac`` of peak."""
    s = step.astype(jnp.float32)
    warm = s / jnp.maximum(warmup, 1)
    prog = jnp.clip((s - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
    cos = min_frac + (1 - min_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return jnp.where(s < warmup, warm, cos)
