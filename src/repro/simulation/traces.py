"""Arrival-rate traces.

The paper modulates a Poisson process with three real traces (Fig. 5):
the FIFA World Cup '98 HTTP trace and the NLANR T4/T5 traces from the
AutoScale paper [Gandhi et al., TOCS'12], scaled so the maximum arrival
rate matches the cluster capacity.

Those traces are not redistributable here, so :func:`synthetic_trace`
generates seeded profiles with the same qualitative shapes (WC: sharp
event-driven peaks over a low base; T4/T5: smooth diurnal waves), and
:meth:`Trace.from_csv` loads the real ones when available — the benchmark
harness uses the synthetic profiles by default and real CSVs when given.
"""
from __future__ import annotations

import csv
import dataclasses
import math
from typing import List, Sequence

import numpy as np


@dataclasses.dataclass(slots=True)
class Trace:
    """Piecewise-constant arrival-rate profile.

    ``rates[i]`` applies on ``[times[i], times[i+1])``; ``times`` has one
    more entry than ``rates``.
    """

    times: np.ndarray  # (n+1,) bin edges, seconds
    rates: np.ndarray  # (n,) requests/second
    name: str = "trace"

    def __post_init__(self) -> None:
        self.times = np.asarray(self.times, dtype=np.float64)
        self.rates = np.asarray(self.rates, dtype=np.float64)
        if self.times.ndim != 1 or self.rates.ndim != 1:
            raise ValueError("times/rates must be 1-D")
        if len(self.times) != len(self.rates) + 1:
            raise ValueError("need len(times) == len(rates) + 1")
        if np.any(np.diff(self.times) <= 0):
            raise ValueError("times must be strictly increasing")
        if np.any(self.rates < 0):
            raise ValueError("rates must be >= 0")

    @property
    def duration(self) -> float:
        return float(self.times[-1] - self.times[0])

    @property
    def max_rate(self) -> float:
        return float(self.rates.max()) if len(self.rates) else 0.0

    def rate_at(self, t: float) -> float:
        if t < self.times[0] or t >= self.times[-1]:
            return 0.0
        i = int(np.searchsorted(self.times, t, side="right")) - 1
        return float(self.rates[min(i, len(self.rates) - 1)])

    def rate_at_many(self, t: np.ndarray) -> np.ndarray:
        """Vectorized :meth:`rate_at` (thinning acceptance hot path)."""
        t = np.asarray(t, dtype=np.float64)
        idx = np.searchsorted(self.times, t, side="right") - 1
        out = self.rates[np.clip(idx, 0, len(self.rates) - 1)]
        return np.where((t >= self.times[0]) & (t < self.times[-1]), out, 0.0)

    def scaled(self, max_rps: float) -> "Trace":
        """Scale so the peak rate equals ``max_rps`` (paper §3.5)."""
        if self.max_rate <= 0:
            raise ValueError("cannot scale an all-zero trace")
        return Trace(
            times=self.times.copy(),
            rates=self.rates * (max_rps / self.max_rate),
            name=f"{self.name}@{max_rps:g}rps",
        )

    def stretched(self, duration: float) -> "Trace":
        """Linearly re-time the trace to span ``duration`` seconds."""
        t0 = self.times[0]
        span = self.times[-1] - t0
        return Trace(
            times=(self.times - t0) * (duration / span),
            rates=self.rates.copy(),
            name=self.name,
        )

    # ------------------------------------------------------------------- io
    def to_csv(self, path: str) -> None:
        with open(path, "w", newline="") as f:
            w = csv.writer(f)
            w.writerow(["t_start", "rate_rps"])
            for t, r in zip(self.times[:-1], self.rates):
                w.writerow([f"{t:.6f}", f"{r:.6f}"])
            w.writerow([f"{self.times[-1]:.6f}", ""])

    @classmethod
    def from_csv(cls, path: str, name: str = "csv") -> "Trace":
        times: List[float] = []
        rates: List[float] = []
        with open(path, newline="") as f:
            r = csv.reader(f)
            header = next(r)
            for row in r:
                if not row or not row[0]:
                    continue
                times.append(float(row[0]))
                if len(row) > 1 and row[1] != "":
                    rates.append(float(row[1]))
        if len(times) == len(rates):  # no explicit final edge: synthesize
            dt = times[-1] - times[-2] if len(times) >= 2 else 1.0
            times.append(times[-1] + dt)
        return cls(times=np.asarray(times), rates=np.asarray(rates), name=name)


def _add_bursts(prof: np.ndarray, rng, n: int, lo: float, hi: float) -> None:
    """Short rectangular load bursts (in place)."""
    n_bins = len(prof)
    for s0 in rng.integers(0, max(n_bins - 3, 1), size=n):
        w = int(rng.integers(1, 4))
        amp = rng.uniform(lo, hi)
        prof[s0:s0 + w] = np.maximum(prof[s0:s0 + w], amp)


def _smooth(x: np.ndarray, k: int) -> np.ndarray:
    if k <= 1:
        return x
    kernel = np.ones(k) / k
    return np.convolve(np.pad(x, (k // 2, k - 1 - k // 2), mode="edge"), kernel, "valid")


def synthetic_trace(
    kind: str,
    duration: float = 3600.0,
    n_bins: int = 360,
    seed: int = 0,
    noise: float = 0.05,
) -> Trace:
    """Seeded trace profiles shaped like the paper's Fig. 5.

    ``kind``:
      * ``"wc"`` — FIFA WC'98-like: modest base with sharp event peaks.
      * ``"t4"`` — NLANR T4-like: smooth diurnal wave, higher duty cycle.
      * ``"t5"`` — NLANR T5-like: diurnal wave with a secondary bump.
      * ``"constant"`` — flat profile (controls/tests).
    """
    rng = np.random.default_rng(seed)
    u = np.linspace(0.0, 1.0, n_bins, endpoint=False)
    if kind == "wc":
        base = 0.18 + 0.10 * np.sin(2 * math.pi * (u - 0.1))
        peaks = (
            0.55 * np.exp(-0.5 * ((u - 0.35) / 0.035) ** 2)
            + 1.00 * np.exp(-0.5 * ((u - 0.72) / 0.05) ** 2)
            + 0.30 * np.exp(-0.5 * ((u - 0.55) / 0.02) ** 2)
        )
        prof = base + peaks
        # flash crowds: the WC'98 trace spikes on goal events within
        # seconds — rectangular bursts a stable-window autoscaler cannot
        # anticipate (these, not the diurnal shape, drive the baseline's
        # SLO violations in Table 3)
        _add_bursts(prof, rng, n=max(3, n_bins // 90), lo=0.45, hi=0.75)
    elif kind == "t4":
        prof = 0.45 + 0.40 * np.sin(2 * math.pi * (u - 0.25)) ** 1
        prof = np.maximum(prof, 0.12)
        _add_bursts(prof, rng, n=max(2, n_bins // 150), lo=0.7, hi=0.95)
    elif kind == "t5":
        prof = (
            0.35
            + 0.35 * np.sin(2 * math.pi * (u - 0.3))
            + 0.18 * np.sin(4 * math.pi * (u - 0.05))
        )
        prof = np.maximum(prof, 0.10)
        _add_bursts(prof, rng, n=max(2, n_bins // 150), lo=0.6, hi=0.9)
    elif kind == "constant":
        prof = np.ones_like(u)
    else:
        raise ValueError(f"unknown trace kind {kind!r}")
    if noise > 0:
        prof = prof * (1.0 + noise * _smooth(rng.standard_normal(n_bins), 9))
    prof = np.maximum(prof, 0.01)
    prof = prof / prof.max()
    times = np.linspace(0.0, duration, n_bins + 1)
    return Trace(times=times, rates=prof, name=kind)
