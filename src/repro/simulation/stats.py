"""Growable numeric buffers for the simulator's metrics hot path.

The event core used to keep ``List[Request]`` plus per-sample rebuilt
Python lists and re-ran ``np.percentile`` over them; at millions of
requests those scans dominate the run. These helpers keep everything in
amortized-O(1)-append float64 storage that exposes zero-copy views for
vectorized reductions at sample/result time.
"""
from __future__ import annotations

import numpy as np


class FloatBuffer:
    """Amortized-O(1) append float64 buffer with a zero-copy view."""

    __slots__ = ("_arr", "_n")

    def __init__(self, capacity: int = 1024) -> None:
        self._arr = np.empty(max(1, capacity), dtype=np.float64)
        self._n = 0

    def append(self, x: float) -> None:
        arr = self._arr
        n = self._n
        if n == arr.shape[0]:
            grown = np.empty(2 * n, dtype=np.float64)
            grown[:n] = arr
            self._arr = arr = grown
        arr[n] = x
        self._n = n + 1

    def __len__(self) -> int:
        return self._n

    def view(self) -> np.ndarray:
        """Zero-copy view of the filled prefix (invalidated by append)."""
        return self._arr[: self._n]


class CompletionLog:
    """Per-completion record of (completion time, e2e latency, arrival time).

    Completion times are appended in event order, hence non-decreasing —
    which makes the trailing-window query a binary search instead of the
    deque-prune-plus-rebuild the sampler used to do.
    """

    __slots__ = ("t_done", "e2e", "arrival")

    def __init__(self) -> None:
        self.t_done = FloatBuffer()
        self.e2e = FloatBuffer()
        self.arrival = FloatBuffer()

    def append(self, t_done: float, e2e: float, arrival: float) -> None:
        self.t_done.append(t_done)
        self.e2e.append(e2e)
        self.arrival.append(arrival)

    def __len__(self) -> int:
        return len(self.e2e)

    def window(self, cutoff: float) -> np.ndarray:
        """Latencies of completions with ``t_done >= cutoff`` (zero-copy)."""
        t = self.t_done.view()
        return self.e2e.view()[int(np.searchsorted(t, cutoff, side="left")):]
