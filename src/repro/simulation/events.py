"""Deterministic discrete-event queue.

Events are ``(time, seq, callback)``; ``seq`` is a monotone tie-breaker so
same-timestamp events fire in insertion order, which keeps runs bit-for-bit
reproducible for a fixed seed.
"""
from __future__ import annotations

import heapq
import itertools
from typing import Callable, Optional

Callback = Callable[[float], None]


class EventQueue:
    __slots__ = ("_heap", "_seq")

    def __init__(self) -> None:
        self._heap = []
        self._seq = itertools.count()

    def push(self, time: float, fn: Callback) -> None:
        if time != time:  # NaN guard
            raise ValueError("event time is NaN")
        heapq.heappush(self._heap, (time, next(self._seq), fn))

    def pop(self):
        time, _, fn = heapq.heappop(self._heap)
        return time, fn

    def peek_time(self) -> Optional[float]:
        return self._heap[0][0] if self._heap else None

    def __len__(self) -> int:
        return len(self._heap)

    def __bool__(self) -> bool:
        return bool(self._heap)
