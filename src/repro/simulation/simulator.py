"""End-to-end discrete-event simulation: arrivals → policy → platform.

Reproduces the paper's experimental pipeline (§3): a Poisson client
(optionally trace-modulated) sends requests to the front-end policy
(MLProxy or a baseline); the policy dispatches batches to the simulated
Knative platform; completions flow back through the policy's monitor.

Outputs match the paper's reporting: average container count (cost),
SLO-violation percentage, average batch size (Table 3), the CCDF of
response times (Fig. 6) and time series of P95 / containers / miss rate /
Max_BS (Fig. 7).

Two drivers share the event machinery:

* :class:`Simulator` — the paper's single-endpoint pipeline (one policy,
  one platform).
* :class:`MultiEndpointSimulator` — beyond paper: drives a
  :class:`~repro.core.frontend.ProxyFrontend` with per-endpoint arrival
  processes, per-endpoint SLAs/policies, and per-endpoint *or shared*
  :class:`~repro.serverless.platform.ServerlessPlatform` fleets (shared
  fleets use :class:`~repro.serverless.latency.EndpointRoutedLatency` to
  give each endpoint its own service-time model).

Event-core design notes (the scale hot path):

* Arrivals are presampled in numpy blocks through
  :class:`_ArrivalPump` (one cursor per arrival process) instead of one
  scalar RNG draw + closure per request.
* The simulator RNG is split into three named spawned streams —
  *arrivals*, *service*, *faults* — so block-sampling arrivals can never
  reorder service-time or fault draws (one-time break in seed
  compatibility with earlier revisions; per-seed determinism is
  unaffected).
* Policy timers are generation-stamped: superseded heap entries are
  dropped on pop instead of spuriously invoking ``policy.on_timer``.
* Completion metrics accumulate into growable float buffers
  (:mod:`repro.simulation.stats`); the sampler's windowed P95 is a binary
  search + vectorized percentile, not a rebuilt Python list.
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Dict, List, Optional

import numpy as np

from repro.core import ProxyFrontend, ProxyConfig, SLAConfig
from repro.core.policies import make_policy
from repro.core.request import Batch, Request
from repro.serverless.latency import EndpointRoutedLatency, LatencyModel
from repro.serverless.platform import PlatformConfig, ServerlessPlatform
from repro.serverless.tiers import TieredPlatform, TierSpec, make_router
from repro.simulation.arrivals import ArrivalProcess
from repro.simulation.events import EventQueue
from repro.simulation.stats import CompletionLog


@dataclasses.dataclass(slots=True)
class SimResult:
    summary: Dict[str, float]
    e2e_latencies: np.ndarray  # seconds, one per completed request
    arrival_times: np.ndarray
    timeline: Dict[str, np.ndarray]  # sampled time series
    policy_stats: Dict[str, float]

    def ccdf(self):
        """Return (latency_sorted, ccdf) for Fig.6-style plots."""
        lat = np.sort(self.e2e_latencies)
        n = len(lat)
        if n == 0:
            return lat, lat
        ccdf = 1.0 - (np.arange(1, n + 1) / n)
        return lat, ccdf


class _ArrivalPump:
    """Cursor over :meth:`ArrivalProcess.next_arrivals` windows.

    Sweeps contiguous ``(clock, clock + horizon]`` windows, buffering each
    block as plain Python floats; :meth:`next` hands out one arrival at a
    time to the event loop. The horizon adapts so blocks stay in a
    cache-friendly size band regardless of arrival rate.
    """

    __slots__ = ("proc", "rng", "end", "clock", "horizon", "buf", "idx")

    _MIN_H, _MAX_H = 0.25, 512.0
    _TARGET_LO, _TARGET_HI = 4096, 131072

    def __init__(self, proc: ArrivalProcess, rng: np.random.Generator,
                 duration: float, horizon: float = 8.0) -> None:
        proc.reset()
        self.proc = proc
        self.rng = rng
        self.end = duration
        self.clock = 0.0
        self.horizon = horizon
        self.buf: List[float] = []
        self.idx = 0

    def next(self) -> Optional[float]:
        idx = self.idx
        buf = self.buf
        while idx >= len(buf):
            if self.clock >= self.end:
                return None
            h = min(self.horizon, self.end - self.clock)
            block = self.proc.next_arrivals(self.clock, self.rng, h)
            self.clock += h
            n = len(block)
            if n >= self._TARGET_HI:
                self.horizon = max(self._MIN_H, self.horizon * 0.5)
            elif n < self._TARGET_LO:
                self.horizon = min(self._MAX_H, self.horizon * 2.0)
            buf = block.tolist()
            self.buf = buf
            idx = 0
        self.idx = idx + 1
        return buf[idx]


class _ProxyHedger:
    """Proxy-tier straggler hedging for the simulators.

    Mirror of the live runtime's hedged dispatch: when a dispatched batch
    is still unfinished after the configured quantile of its bucket's
    measured upstream latency, a shadow copy is re-submitted to the
    platform; the first completion wins (stamping ``attempts`` with the
    extra attempt) and the loser's completion is swallowed. The sim
    cannot *cancel* platform-side work the way the runtime cancels its
    loser task — on a transparent platform (the parity configuration)
    that is observationally identical; on a capacity-bound fleet the
    loser briefly occupies a slot until it finishes.

    All mappings key on ``id()`` of batches that the state dict itself
    keeps alive, so keys cannot be recycled while tracked.
    """

    __slots__ = ("quantile", "min_samples", "events", "submit_fn",
                 "monitor_fn", "_state", "_shadow_owner", "hedged", "wins",
                 "hedged_by_ep", "wins_by_ep")

    def __init__(self, quantile: float, min_samples: int, events: EventQueue,
                 submit_fn, monitor_fn) -> None:
        if quantile < 1 or quantile > 100:
            # percentile units, same contract as RuntimeConfig: a
            # fraction like 0.95 would hedge at the bucket minimum
            raise ValueError(
                f"hedge_quantile is in percentile units ((1, 100], e.g. "
                f"95.0), got {quantile}"
            )
        self.quantile = quantile
        self.min_samples = min_samples
        self.events = events
        self.submit_fn = submit_fn      # (batch, now) -> platform submit
        self.monitor_fn = monitor_fn    # (batch) -> SmartMonitor
        # id(primary) → [primary, shadow|None, first_completion_seen]
        self._state: Dict[int, list] = {}
        self._shadow_owner: Dict[int, Batch] = {}
        self.hedged = 0
        self.wins = 0
        # per-endpoint splits of the two counters above (key "" for the
        # single-endpoint simulator, whose batches carry no endpoint)
        self.hedged_by_ep: Dict[str, int] = {}
        self.wins_by_ep: Dict[str, int] = {}

    def on_dispatch(self, batch: Batch, now: float) -> None:
        """Arm the straggler timer for a freshly dispatched batch."""
        monitor = self.monitor_fn(batch)
        threshold = monitor.bucket_quantile(
            batch.effective_size, self.quantile, now, self.min_samples
        )
        if threshold is None:
            return  # bucket still cold: hedging stays off (same as live)
        self._state[id(batch)] = [batch, None, False]
        self.events.push(now + threshold, partial(self._maybe_hedge, batch))

    def _maybe_hedge(self, batch: Batch, now: float) -> None:
        st = self._state.get(id(batch))
        if st is None or st[2] or st[1] is not None:
            return  # already completed (or already hedged)
        shadow = Batch(requests=batch.requests,
                       dispatch_time=batch.dispatch_time, cause=batch.cause,
                       bucket_size=batch.bucket_size, endpoint=batch.endpoint,
                       tier=batch.tier)
        st[1] = shadow
        self._shadow_owner[id(shadow)] = batch
        self.hedged += 1
        ep = batch.endpoint or ""
        self.hedged_by_ep[ep] = self.hedged_by_ep.get(ep, 0) + 1
        self.submit_fn(shadow, now)

    def resolve(self, batch: Batch, latency: float, now: float):
        """Map a platform completion onto its primary batch.

        Returns ``(primary, latency)`` for a winning completion or
        ``None`` for a hedge loser whose completion must be ignored.
        """
        owner = self._shadow_owner.get(id(batch))
        primary = owner if owner is not None else batch
        st = self._state.get(id(primary))
        if st is None:
            return primary, latency  # untracked: hedging never armed
        if st[2]:
            # loser: the sibling already completed this work
            shadow = st[1]
            if shadow is not None:
                self._shadow_owner.pop(id(shadow), None)
            del self._state[id(primary)]
            return None
        st[2] = True
        if st[1] is None:
            del self._state[id(primary)]  # finished before the timer fired
            return primary, latency
        # hedged and first across the line: stamp the extra attempt and
        # measure latency from the PRIMARY dispatch (what the proxy saw),
        # exactly as the live runtime's `now - t0` does.
        if owner is not None:
            self.wins += 1
            ep = primary.endpoint or ""
            self.wins_by_ep[ep] = self.wins_by_ep.get(ep, 0) + 1
        primary.attempts = batch.attempts + 1
        return primary, now - primary.dispatch_time


class _EventLoopDriver:
    """Timer wiring + run/flush/drain loop shared by both simulators.

    Subclasses provide ``events``/``now``/``duration``/``drain_grace`` and
    :meth:`_control` returning the Policy-like front object
    (``next_event_time``/``on_timer``/``flush``).

    Policy timers are generation-stamped: every (re)schedule bumps
    ``_timer_gen`` and the stamped value rides the heap entry, so an entry
    superseded by an earlier reschedule is dropped on pop instead of
    calling ``policy.on_timer`` at a stale deadline.
    """

    events: EventQueue
    now: float
    duration: float
    drain_grace: float
    _timer_scheduled_at: Optional[float]
    _timer_gen: int
    events_processed: int

    def _control(self):
        raise NotImplementedError

    def _on_policy_timer(self, gen: int, now: float) -> None:
        if gen != self._timer_gen:
            return  # superseded heap entry: a later reschedule owns the timer
        self._timer_scheduled_at = None
        self._control().on_timer(now)
        self._reschedule_policy_timer(min_time=now + 1e-6)

    def _reschedule_policy_timer(self, min_time: float = 0.0) -> None:
        t = self._control().next_event_time(self.now)
        if t is None:
            return
        # min_time guards against zero-progress loops when a policy keeps
        # requesting the instant a timer just served
        t = max(t, self.now, min_time)
        if self._timer_scheduled_at is None or t < self._timer_scheduled_at - 1e-12:
            self._timer_scheduled_at = t
            self._timer_gen += 1
            self.events.push(t, partial(self._on_policy_timer, self._timer_gen))

    def _drive(self) -> float:
        """Run events through duration + drain grace, flushing queued
        batches at end-of-run; returns the hard-stop time."""
        hard_stop = self.duration + self.drain_grace
        flushed = False
        events = self.events
        n_events = 0
        while events:
            t, fn = events.pop()
            if t > hard_stop:
                break
            self.now = t
            if not flushed and t >= self.duration:
                self._control().flush(self.now)
                flushed = True
            fn(t)
            n_events += 1
        if not flushed:
            self._control().flush(self.now)
        # drain remaining completions
        while events:
            t, fn = events.pop()
            if t > hard_stop:
                break
            self.now = t
            fn(t)
            n_events += 1
        self.events_processed += n_events
        return hard_stop


def _spawn_streams(seed: int):
    """(arrivals, service, faults) generators from one root seed.

    Named spawned streams keep the three draw categories independent:
    block-sampling arrivals consumes only the arrivals stream, so service
    times and fault outcomes for a given seed do not shift when the
    arrival path (or its chunking) changes.
    """
    arr_ss, svc_ss, fault_ss = np.random.SeedSequence(seed).spawn(3)
    return (
        np.random.default_rng(arr_ss),
        np.random.default_rng(svc_ss),
        np.random.default_rng(fault_ss),
    )


class Simulator(_EventLoopDriver):
    def __init__(
        self,
        *,
        policy: str,
        sla: SLAConfig,
        workload: LatencyModel,
        arrivals: ArrivalProcess,
        platform_config: Optional[PlatformConfig] = None,
        policy_kwargs: Optional[dict] = None,
        duration: float = 600.0,
        warmup: float = 0.0,
        drain_grace: float = 120.0,
        sample_interval: float = 5.0,
        p95_window: float = 60.0,
        seed: int = 0,
        hedge_quantile: float = 0.0,
        hedge_min_samples: int = 10,
        tracer=None,
        recorder=None,
    ) -> None:
        self.sla = sla
        self.workload = workload
        self.arrivals = arrivals
        self.duration = duration
        self.warmup = warmup
        self.drain_grace = drain_grace
        self.sample_interval = sample_interval
        self.p95_window = p95_window
        self.rng_arrivals, self.rng, self.rng_faults = _spawn_streams(seed)
        self.events = EventQueue()
        self.now = 0.0
        self.events_processed = 0
        # optional observability plane (same seam as the live runtime:
        # None — the default — keeps the hot path byte-identical)
        self.tracer = tracer
        self.recorder = recorder

        self.platform = ServerlessPlatform(
            config=platform_config or PlatformConfig(),
            latency_model=workload,
            events=self.events,
            rng=self.rng,
            fault_rng=self.rng_faults,
            on_batch_done=self._on_batch_done,
            tracer=tracer,
            recorder=recorder,
        )
        self.policy = make_policy(
            policy, sla, self._dispatch, tracer=tracer,
            **(policy_kwargs or {})
        )
        # per-request absolute deadlines (None disables — the default)
        self._deadline_budget = sla.deadline_budget
        self.arrived_requests = 0
        # proxy-tier straggler hedging (sim mirror of the live runtime's)
        self._hedger: Optional[_ProxyHedger] = None
        if hedge_quantile > 0:
            self._hedger = _ProxyHedger(
                hedge_quantile, hedge_min_samples, self.events,
                submit_fn=lambda b, t: self.platform.submit(b, t),
                monitor_fn=lambda b: self.policy.monitor,
            )

        self.completions = CompletionLog()
        self._pump = _ArrivalPump(arrivals, self.rng_arrivals, duration)
        self._on_arrival_cb = self._on_arrival  # bound once, reused per event
        self._timer_scheduled_at: Optional[float] = None
        self._timer_gen = 0
        self._samples: List[dict] = []

    # --------------------------------------------------------------- wiring
    def _dispatch(self, batch: Batch) -> None:
        self.platform.submit(batch, self.now)
        if self._hedger is not None:
            self._hedger.on_dispatch(batch, self.now)

    def _on_batch_done(self, batch: Batch, upstream_latency: float, now: float) -> None:
        if self._hedger is not None:
            resolved = self._hedger.resolve(batch, upstream_latency, now)
            if resolved is None:
                return  # hedge loser: the sibling already completed this
            batch, upstream_latency = resolved
        self.policy.on_response(batch, upstream_latency, now)
        log = self.completions
        for r in batch.requests:
            log.append(now, now - r.arrival_time, r.arrival_time)
        self._reschedule_policy_timer()

    def _on_arrival(self, now: float) -> None:
        self.arrived_requests += 1
        req = Request(arrival_time=now)
        if self._deadline_budget is not None:
            req.deadline = now + self._deadline_budget
        if self.tracer is not None:
            # no frontend in the single-endpoint pipeline, so the driver
            # stamps admission itself (the multi-endpoint path gets this
            # from ProxyFrontend.on_request)
            self.tracer.emit(now, "admitted", "", req_id=req.req_id)
        self.policy.on_request(req, now)
        nxt = self._pump.next()
        if nxt is not None:
            self.events.push(nxt, self._on_arrival_cb)
        self._reschedule_policy_timer()

    def _control(self):
        return self.policy

    # --------------------------------------------------------------- metrics
    def _on_sample(self, now: float) -> None:
        lats = self.completions.window(now - self.p95_window)
        n = len(lats)
        if n:
            p95 = float(np.percentile(lats, 95))
            miss = float(np.count_nonzero(lats > self.sla.slo_target)) / n
        else:
            p95 = math.nan
            miss = math.nan
        self._samples.append(
            {
                "t": now,
                "p95": p95,
                "miss_rate": miss,
                "containers": self.platform.billable_count,
                "ready": self.platform.ready_count(now),
                "queued_batches": self.platform.queued_batches,
                "max_bs": float(self.policy.max_bs),
                "proxy_queue": self.policy.stats(now).get("queue_len", 0),
            }
        )
        if now < self.duration + self.drain_grace:
            self.events.push(now + self.sample_interval, self._on_sample)

    # ------------------------------------------------------------------ run
    def run(self) -> SimResult:
        first = self._pump.next()
        if first is not None:
            self.events.push(first, self._on_arrival_cb)
        self.events.push(0.0, self._on_sample)
        self.platform.start(0.0)
        if self.warmup > 0:
            self.events.push(self.warmup, self.platform.reset_billing)

        hard_stop = self._drive()
        self.platform.finalize(min(self.now, hard_stop))
        return self._result()

    def _result(self) -> SimResult:
        all_e2e = self.completions.e2e.view()
        all_arr = self.completions.arrival.view()
        keep = all_arr >= self.warmup
        e2e = all_e2e[keep]
        arr = all_arr[keep]
        viol = float(np.mean(e2e > self.sla.slo_target)) if len(e2e) else 0.0
        pstats = self.policy.stats(self.now)
        billing_window = max(self.now, self.duration) - self.warmup
        summary = {
            "completed": float(len(e2e)),
            "violation_rate": viol,
            "violation_pct": 100.0 * viol,
            "avg_containers": self.platform.avg_containers(billing_window),
            # cost is a billable-seconds integral (avg_containers × window),
            # surfaced directly so cost reports need no re-derivation
            "cost_integral": float(self.platform.cost_integral),
            "peak_containers": float(self.platform.peak_containers),
            "avg_batch_size": pstats.get("avg_batch_size", 0.0),
            "p50": float(np.percentile(e2e, 50)) if len(e2e) else math.nan,
            "p95": float(np.percentile(e2e, 95)) if len(e2e) else math.nan,
            "p99": float(np.percentile(e2e, 99)) if len(e2e) else math.nan,
            "mean_latency": float(e2e.mean()) if len(e2e) else math.nan,
            "cold_starts": float(self.platform.cold_starts),
            "failed_attempts": float(self.platform.failed_attempts),
            "hedged_dispatches": float(self.platform.hedged_dispatches),
            "throughput": float(len(e2e)) / max(self.now, 1e-9),
            # deadline / proxy-hedge accounting (identical semantics to
            # the live runtime's summary keys)
            "submitted_requests": float(self.arrived_requests),
            "timed_out": float(pstats.get("expired", 0)),
            "hedged_batches": float(self._hedger.hedged
                                    if self._hedger else 0),
            "hedge_wins": float(self._hedger.wins if self._hedger else 0),
            # event-core work counter + queue high-water mark + SLO burn,
            # under the SAME key names as the live runtime's summary()
            "events_processed": float(self.events_processed),
            "queue_depth_hwm": float(pstats.get("queue_depth_hwm", 0)),
            "burn_rate_fast": float(pstats.get("burn_rate_fast", 0.0)),
            "burn_rate_slow": float(pstats.get("burn_rate_slow", 0.0)),
        }
        # conservation ledger: every submitted batch must be completed or
        # still accounted for (queued/in-flight); lost and duplicate must
        # stay 0 in every run, faults or not
        cons = self.platform.conservation()
        summary.update(
            {
                "submitted_batches": float(cons["submitted_batches"]),
                "completed_batches": float(cons["completed_batches"]),
                "outstanding_batches": float(cons["outstanding_batches"]),
                "lost_batches": float(cons["lost_batches"]),
                "duplicate_completions": float(cons["duplicate_completions"]),
                "requeued_batches": float(cons["requeued_batches"]),
                "cancelled_attempts": float(cons["cancelled_attempts"]),
                "preemptions": float(cons["preemptions"]),
            }
        )
        timeline = {
            k: np.asarray([s[k] for s in self._samples], dtype=np.float64)
            for k in (self._samples[0].keys() if self._samples else [])
        }
        return SimResult(
            summary=summary,
            e2e_latencies=e2e,
            arrival_times=arr,
            timeline=timeline,
            policy_stats={k: v for k, v in pstats.items() if isinstance(v, (int, float))},
        )


def run_simulation(**kwargs) -> SimResult:
    """Convenience wrapper: ``run_simulation(policy=..., sla=..., ...)``."""
    return Simulator(**kwargs).run()


# ---------------------------------------------------------------------------
# Multi-endpoint scenario layer (beyond paper)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(slots=True)
class EndpointSpec:
    """Everything one endpoint needs in a multi-endpoint scenario.

    ``platform`` names a shared-fleet group: endpoints with the same key
    run on one :class:`ServerlessPlatform` (multi-model serving); ``None``
    gives the endpoint a dedicated fleet. ``platform_config`` is taken from
    the first group member that sets one.

    ``tiers`` (a tuple of :class:`~repro.serverless.tiers.TierSpec`)
    upgrades the endpoint's fleet to a :class:`TieredPlatform` and gives
    the endpoint a :class:`~repro.core.frontend.SpilloverRouter` over
    those tiers; every member of a shared group must declare the same
    tier list. ``platform_config`` (or the group's) becomes the base
    config tiers inherit from.
    """

    policy: str
    sla: SLAConfig
    workload: LatencyModel
    arrivals: ArrivalProcess
    policy_kwargs: Optional[dict] = None
    platform: Optional[str] = None
    platform_config: Optional[PlatformConfig] = None
    tiers: Optional[tuple] = None  # Tuple[TierSpec, ...]


@dataclasses.dataclass(slots=True)
class MultiSimResult:
    summary: Dict[str, float]                    # fleet-level aggregate
    endpoints: Dict[str, Dict[str, float]]       # per-endpoint summaries
    e2e_latencies: Dict[str, np.ndarray]         # per-endpoint latencies
    frontend_stats: dict
    # per-tier breakdowns, populated only for tiered fleets so the
    # summary/endpoints surfaces above stay byte-comparable with
    # untirered runs: platform-group key → tier name → metrics, and
    # endpoint → SpilloverRouter.stats()
    tiers: Dict[str, dict] = dataclasses.field(default_factory=dict)
    routers: Dict[str, dict] = dataclasses.field(default_factory=dict)


class MultiEndpointSimulator(_EventLoopDriver):
    """Drives one :class:`ProxyFrontend` over N endpoints in one event loop.

    Each endpoint has its own arrival process, SLA, policy, and (dedicated
    or shared) platform; the frontend merges every policy's timer into one
    clock, exactly as a single proxy process would in production. Each
    endpoint's arrival pump runs on its own spawned child of the arrivals
    stream, so per-endpoint block sampling stays order-independent.
    """

    def __init__(
        self,
        endpoints: Dict[str, EndpointSpec],
        *,
        duration: float = 600.0,
        warmup: float = 0.0,
        drain_grace: float = 120.0,
        seed: int = 0,
        hedge_quantile: float = 0.0,
        hedge_min_samples: int = 10,
        tracer=None,
        recorder=None,
    ) -> None:
        if not endpoints:
            raise ValueError("need at least one endpoint")
        self.specs = dict(endpoints)
        self.duration = duration
        self.warmup = warmup
        self.drain_grace = drain_grace
        arr_ss, svc_ss, fault_ss = np.random.SeedSequence(seed).spawn(3)
        self.rng = np.random.default_rng(svc_ss)
        self.rng_faults = np.random.default_rng(fault_ss)
        self.events = EventQueue()
        self.now = 0.0
        self.events_processed = 0
        self.tracer = tracer
        self.recorder = recorder

        # platform groups: shared key → one fleet; None → dedicated fleet
        groups: Dict[str, List[str]] = {}
        for name, spec in self.specs.items():
            key = spec.platform if spec.platform is not None else f"dedicated:{name}"
            groups.setdefault(key, []).append(name)
        # values are ServerlessPlatform or TieredPlatform (same surface)
        self.platforms: Dict[str, ServerlessPlatform] = {}
        self._platform_of: Dict[str, str] = {}
        for key, members in groups.items():
            if len(members) == 1:
                latency: LatencyModel = self.specs[members[0]].workload
            else:
                latency = EndpointRoutedLatency(
                    {m: self.specs[m].workload for m in members}
                )
            pc = next(
                (self.specs[m].platform_config for m in members
                 if self.specs[m].platform_config is not None),
                None,
            )
            tier_lists = {m: tuple(self.specs[m].tiers)
                          for m in members if self.specs[m].tiers}
            if tier_lists and len(set(tier_lists.values())) > 1:
                raise ValueError(
                    f"platform group {key!r}: members disagree on tiers "
                    f"({sorted(tier_lists)})")
            if tier_lists:
                self.platforms[key] = TieredPlatform(
                    next(iter(tier_lists.values())),
                    latency_model=latency,
                    events=self.events,
                    rng=self.rng,
                    on_batch_done=self._on_batch_done,
                    base_config=pc or PlatformConfig(),
                    fault_rng=self.rng_faults,
                    tracer=tracer,
                    recorder=recorder,
                )
            else:
                self.platforms[key] = ServerlessPlatform(
                    config=pc or PlatformConfig(),
                    latency_model=latency,
                    events=self.events,
                    rng=self.rng,
                    fault_rng=self.rng_faults,
                    on_batch_done=self._on_batch_done,
                    tracer=tracer,
                    recorder=recorder,
                )
            for m in members:
                self._platform_of[m] = key

        # proxy-tier hedging shared across endpoints (shadow batches are
        # routed to their endpoint's platform by the stamped endpoint key)
        self._hedger: Optional[_ProxyHedger] = None
        if hedge_quantile > 0:
            self._hedger = _ProxyHedger(
                hedge_quantile, hedge_min_samples, self.events,
                submit_fn=lambda b, t: self.platforms[
                    self._platform_of[b.endpoint]].submit(b, t),
                monitor_fn=lambda b: self.frontend.endpoint(
                    b.endpoint).policy.monitor,
            )

        self.frontend = ProxyFrontend(tracer=tracer)
        for name, spec in self.specs.items():
            plat = self.platforms[self._platform_of[name]]
            router = None
            if spec.tiers:
                # one router per endpoint (per-endpoint in-flight signals)
                # probing the shared fleet's per-tier platform queues
                router = make_router(spec.tiers,
                                     queue_probe=plat.tier_queue_depth,
                                     tracer=tracer)
            self.frontend.add_endpoint(
                name,
                sla=spec.sla,
                dispatch_fn=partial(self._dispatch_batch, plat),
                policy=spec.policy,
                policy_kwargs=spec.policy_kwargs,
                router=router,
            )
        self.arrived_requests: Dict[str, int] = {n: 0 for n in self.specs}

        # one spawned arrivals stream + one pump + one reusable arrival
        # callback per endpoint (registration order is deterministic)
        arr_children = arr_ss.spawn(len(self.specs))
        self._pumps: Dict[str, _ArrivalPump] = {}
        self._arrival_cbs: Dict[str, partial] = {}
        for (name, spec), child in zip(self.specs.items(), arr_children):
            self._pumps[name] = _ArrivalPump(
                spec.arrivals, np.random.default_rng(child), duration
            )
            self._arrival_cbs[name] = partial(self._on_arrival, name)

        self.completions: Dict[str, CompletionLog] = {
            n: CompletionLog() for n in self.specs
        }
        self._timer_scheduled_at: Optional[float] = None
        self._timer_gen = 0

    # --------------------------------------------------------------- wiring
    def _control(self):
        return self.frontend

    def _dispatch_batch(self, plat: ServerlessPlatform, batch: Batch) -> None:
        plat.submit(batch, self.now)
        if self._hedger is not None:
            self._hedger.on_dispatch(batch, self.now)

    def _on_batch_done(self, batch: Batch, upstream_latency: float, now: float) -> None:
        if self._hedger is not None:
            resolved = self._hedger.resolve(batch, upstream_latency, now)
            if resolved is None:
                return  # hedge loser
            batch, upstream_latency = resolved
        self.frontend.on_response(batch, upstream_latency, now)
        log = self.completions[batch.endpoint]
        for r in batch.requests:
            log.append(now, now - r.arrival_time, r.arrival_time)
        self._reschedule_policy_timer()

    def _on_arrival(self, name: str, now: float) -> None:
        self.arrived_requests[name] += 1
        # frontend.on_request derives the deadline from the endpoint SLA
        self.frontend.on_request(Request(arrival_time=now, endpoint=name), now)
        nxt = self._pumps[name].next()
        if nxt is not None:
            self.events.push(nxt, self._arrival_cbs[name])
        self._reschedule_policy_timer()

    # ------------------------------------------------------------------ run
    def run(self) -> MultiSimResult:
        for name in self.specs:
            first = self._pumps[name].next()
            if first is not None:
                self.events.push(first, self._arrival_cbs[name])
        for plat in self.platforms.values():
            plat.start(0.0)
            if self.warmup > 0:
                self.events.push(self.warmup, plat.reset_billing)

        hard_stop = self._drive()
        for plat in self.platforms.values():
            plat.finalize(min(self.now, hard_stop))
        return self._result()

    def _result(self) -> MultiSimResult:
        billing_window = max(self.now, self.duration) - self.warmup
        fstats = self.frontend.stats(self.now)
        endpoints: Dict[str, Dict[str, float]] = {}
        latencies: Dict[str, np.ndarray] = {}
        for name, spec in self.specs.items():
            log = self.completions[name]
            keep = log.arrival.view() >= self.warmup
            e2e = log.e2e.view()[keep]
            latencies[name] = e2e
            viol = float(np.mean(e2e > spec.sla.slo_target)) if len(e2e) else 0.0
            ep_stats = fstats["endpoints"][name]
            hedger = self._hedger
            endpoints[name] = {
                "completed": float(len(e2e)),
                "slo_target": spec.sla.slo_target,
                "violation_rate": viol,
                "violation_pct": 100.0 * viol,
                "avg_batch_size": ep_stats.get("avg_batch_size", 0.0),
                "dispatched_batches": float(
                    ep_stats.get("dispatched_batches", 0)),
                "max_bs": float(ep_stats.get("max_bs", 1)),
                "p50": float(np.percentile(e2e, 50)) if len(e2e) else math.nan,
                "p95": float(np.percentile(e2e, 95)) if len(e2e) else math.nan,
                "mean_latency": float(e2e.mean()) if len(e2e) else math.nan,
                # per-endpoint retry accounting (platform-side crash
                # retries + hedges observed through Batch.attempts); PR 2
                # surfaced only the fleet aggregate
                "upstream_batches": float(ep_stats.get("upstream_batches", 0)),
                "retried_batches": float(ep_stats.get("retried_batches", 0)),
                "retry_rate": float(ep_stats.get("retry_rate", 0.0)),
                "failure_rate": float(ep_stats.get("failure_rate", 0.0)),
                # deadline accounting (mirrors the live runtime summary)
                "submitted_requests": float(self.arrived_requests[name]),
                "timed_out": float(ep_stats.get("expired", 0)),
                "shed": float(ep_stats.get("shed", 0)),
                "padding_waste": float(ep_stats.get("padding_waste", 0.0)),
                # observability surface: identical key names to the live
                # runtime's per-endpoint summary (sim↔live parity-tested)
                "queue_depth_hwm": float(ep_stats.get("queue_depth_hwm", 0)),
                "burn_rate_fast": float(ep_stats.get("burn_rate_fast", 0.0)),
                "burn_rate_slow": float(ep_stats.get("burn_rate_slow", 0.0)),
                "hedged_batches": float(
                    hedger.hedged_by_ep.get(name, 0) if hedger else 0),
                "hedge_wins": float(
                    hedger.wins_by_ep.get(name, 0) if hedger else 0),
            }
        total_containers = sum(
            p.avg_containers(billing_window) for p in self.platforms.values()
        )
        all_completed = sum(s["completed"] for s in endpoints.values())
        # fleet violation rate weighted by each endpoint's completed count
        agg_viol = (
            sum(s["violation_rate"] * s["completed"] for s in endpoints.values())
            / all_completed
            if all_completed
            else 0.0
        )
        # weighted cost: Σ platform cost_integral (TieredPlatform applies
        # per-tier cost weights; a plain platform's integral is weight-1.0,
        # so untirered and 1-tier runs produce the identical float)
        total_cost = sum(
            p.cost_integral for p in self.platforms.values())
        summary = {
            "completed": all_completed,
            "violation_rate": agg_viol,
            "violation_pct": 100.0 * agg_viol,
            "avg_containers": total_containers,
            "cost_integral": float(total_cost),
            "weighted_cost": float(total_cost / billing_window
                                   if billing_window > 0 else 0.0),
            "peak_containers": float(
                sum(p.peak_containers for p in self.platforms.values())
            ),
            "cold_starts": float(sum(p.cold_starts for p in self.platforms.values())),
            "n_platforms": float(len(self.platforms)),
            "n_endpoints": float(len(self.specs)),
            "submitted_requests": float(sum(self.arrived_requests.values())),
            "timed_out": float(sum(s["timed_out"] for s in endpoints.values())),
            "hedged_batches": float(self._hedger.hedged
                                    if self._hedger else 0),
            "hedge_wins": float(self._hedger.wins if self._hedger else 0),
            "events_processed": float(self.events_processed),
            "queue_depth_hwm": float(
                fstats["aggregate"]["queue_depth_hwm"]),
            "burn_rate_fast": fstats["aggregate"]["burn_rate_fast"],
            "burn_rate_slow": fstats["aggregate"]["burn_rate_slow"],
        }
        # fleet-wide conservation ledger (summed over every platform)
        cons = [p.conservation() for p in self.platforms.values()]
        for key in (
            "submitted_batches",
            "completed_batches",
            "outstanding_batches",
            "lost_batches",
            "duplicate_completions",
            "requeued_batches",
            "cancelled_attempts",
            "preemptions",
        ):
            summary[key] = float(sum(c[key] for c in cons))
        # per-tier breakdowns (tiered fleets only — kept OUT of summary/
        # endpoints so those stay byte-comparable with untirered runs)
        tiers_out: Dict[str, dict] = {}
        for key, p in self.platforms.items():
            if not isinstance(p, TieredPlatform):
                continue
            cost_bt = p.cost_by_tier()
            cons_bt = p.conservation_by_tier()
            tiers_out[key] = {
                tn: {
                    "avg_containers": child.avg_containers(billing_window),
                    "peak_containers": float(child.peak_containers),
                    "cold_starts": float(child.cold_starts),
                    "container_seconds": cost_bt[tn]["container_seconds"],
                    "cost_weight": cost_bt[tn]["cost_weight"],
                    "cost_integral": cost_bt[tn]["cost_integral"],
                    "submitted_batches": float(
                        cons_bt[tn]["submitted_batches"]),
                    "completed_batches": float(
                        cons_bt[tn]["completed_batches"]),
                    "requeued_batches": float(
                        cons_bt[tn]["requeued_batches"]),
                    "preemptions": float(cons_bt[tn]["preemptions"]),
                }
                for tn, child in p.platforms.items()
            }
        routers_out = {
            name: ep.router.stats()
            for name in self.specs
            if (ep := self.frontend.endpoint(name)).router is not None
        }
        return MultiSimResult(
            summary=summary,
            endpoints=endpoints,
            e2e_latencies=latencies,
            frontend_stats=fstats,
            tiers=tiers_out,
            routers=routers_out,
        )


def run_multi_simulation(endpoints: Dict[str, EndpointSpec], **kwargs) -> MultiSimResult:
    """Convenience wrapper: ``run_multi_simulation({"a": EndpointSpec(...)})``."""
    return MultiEndpointSimulator(endpoints, **kwargs).run()
