"""End-to-end discrete-event simulation: arrivals → policy → platform.

Reproduces the paper's experimental pipeline (§3): a Poisson client
(optionally trace-modulated) sends requests to the front-end policy
(MLProxy or a baseline); the policy dispatches batches to the simulated
Knative platform; completions flow back through the policy's monitor.

Outputs match the paper's reporting: average container count (cost),
SLO-violation percentage, average batch size (Table 3), the CCDF of
response times (Fig. 6) and time series of P95 / containers / miss rate /
Max_BS (Fig. 7).

Two drivers share the event machinery:

* :class:`Simulator` — the paper's single-endpoint pipeline (one policy,
  one platform).
* :class:`MultiEndpointSimulator` — beyond paper: drives a
  :class:`~repro.core.frontend.ProxyFrontend` with per-endpoint arrival
  processes, per-endpoint SLAs/policies, and per-endpoint *or shared*
  :class:`~repro.serverless.platform.ServerlessPlatform` fleets (shared
  fleets use :class:`~repro.serverless.latency.EndpointRoutedLatency` to
  give each endpoint its own service-time model).
"""
from __future__ import annotations

import collections
import dataclasses
import math
from typing import Dict, List, Optional

import numpy as np

from repro.core import ProxyFrontend, ProxyConfig, SLAConfig
from repro.core.policies import make_policy
from repro.core.request import Batch, Request
from repro.serverless.latency import EndpointRoutedLatency, LatencyModel
from repro.serverless.platform import PlatformConfig, ServerlessPlatform
from repro.simulation.arrivals import ArrivalProcess
from repro.simulation.events import EventQueue


@dataclasses.dataclass
class SimResult:
    summary: Dict[str, float]
    e2e_latencies: np.ndarray  # seconds, one per completed request
    arrival_times: np.ndarray
    timeline: Dict[str, np.ndarray]  # sampled time series
    policy_stats: Dict[str, float]

    def ccdf(self):
        """Return (latency_sorted, ccdf) for Fig.6-style plots."""
        lat = np.sort(self.e2e_latencies)
        n = len(lat)
        if n == 0:
            return lat, lat
        ccdf = 1.0 - (np.arange(1, n + 1) / n)
        return lat, ccdf


class _EventLoopDriver:
    """Timer wiring + run/flush/drain loop shared by both simulators.

    Subclasses provide ``events``/``now``/``duration``/``drain_grace`` and
    :meth:`_control` returning the Policy-like front object
    (``next_event_time``/``on_timer``/``flush``).
    """

    events: EventQueue
    now: float
    duration: float
    drain_grace: float
    _timer_scheduled_at: Optional[float]

    def _control(self):
        raise NotImplementedError

    def _on_policy_timer(self, now: float) -> None:
        self._timer_scheduled_at = None
        self._control().on_timer(now)
        self._reschedule_policy_timer(min_time=now + 1e-6)

    def _reschedule_policy_timer(self, min_time: float = 0.0) -> None:
        t = self._control().next_event_time(self.now)
        if t is None:
            return
        # min_time guards against zero-progress loops when a policy keeps
        # requesting the instant a timer just served
        t = max(t, self.now, min_time)
        if self._timer_scheduled_at is None or t < self._timer_scheduled_at - 1e-12:
            self._timer_scheduled_at = t
            self.events.push(t, self._on_policy_timer)

    def _drive(self) -> float:
        """Run events through duration + drain grace, flushing queued
        batches at end-of-run; returns the hard-stop time."""
        hard_stop = self.duration + self.drain_grace
        flushed = False
        while self.events:
            t, fn = self.events.pop()
            if t > hard_stop:
                break
            self.now = t
            if not flushed and t >= self.duration:
                self._control().flush(self.now)
                flushed = True
            fn(t)
        if not flushed:
            self._control().flush(self.now)
        # drain remaining completions
        while self.events:
            t, fn = self.events.pop()
            if t > hard_stop:
                break
            self.now = t
            fn(t)
        return hard_stop


class Simulator(_EventLoopDriver):
    def __init__(
        self,
        *,
        policy: str,
        sla: SLAConfig,
        workload: LatencyModel,
        arrivals: ArrivalProcess,
        platform_config: Optional[PlatformConfig] = None,
        policy_kwargs: Optional[dict] = None,
        duration: float = 600.0,
        warmup: float = 0.0,
        drain_grace: float = 120.0,
        sample_interval: float = 5.0,
        p95_window: float = 60.0,
        seed: int = 0,
    ) -> None:
        self.sla = sla
        self.workload = workload
        self.arrivals = arrivals
        self.duration = duration
        self.warmup = warmup
        self.drain_grace = drain_grace
        self.sample_interval = sample_interval
        self.p95_window = p95_window
        self.rng = np.random.default_rng(seed)
        self.events = EventQueue()
        self.now = 0.0

        self.platform = ServerlessPlatform(
            config=platform_config or PlatformConfig(),
            latency_model=workload,
            events=self.events,
            rng=self.rng,
            on_batch_done=self._on_batch_done,
        )
        self.policy = make_policy(
            policy, sla, self._dispatch, **(policy_kwargs or {})
        )

        self.completed: List[Request] = []
        self._recent: collections.deque = collections.deque()  # (t_done, e2e)
        self._timer_scheduled_at: Optional[float] = None
        self._samples: List[dict] = []

    # --------------------------------------------------------------- wiring
    def _dispatch(self, batch: Batch) -> None:
        self.platform.submit(batch, self.now)

    def _on_batch_done(self, batch: Batch, upstream_latency: float, now: float) -> None:
        self.policy.on_response(batch, upstream_latency, now)
        for r in batch.requests:
            self.completed.append(r)
            self._recent.append((now, r.e2e_latency))
        self._reschedule_policy_timer()

    def _on_arrival(self, now: float) -> None:
        req = Request(arrival_time=now)
        self.policy.on_request(req, now)
        nxt = self.arrivals.next_arrival(now, self.rng)
        if nxt is not None:
            self.events.push(nxt, self._on_arrival)
        self._reschedule_policy_timer()

    def _control(self):
        return self.policy

    # --------------------------------------------------------------- metrics
    def _on_sample(self, now: float) -> None:
        cutoff = now - self.p95_window
        while self._recent and self._recent[0][0] < cutoff:
            self._recent.popleft()
        lats = [l for (_, l) in self._recent]
        p95 = float(np.percentile(lats, 95)) if lats else math.nan
        miss = (
            sum(1 for l in lats if l > self.sla.slo_target) / len(lats)
            if lats
            else math.nan
        )
        self._samples.append(
            {
                "t": now,
                "p95": p95,
                "miss_rate": miss,
                "containers": self.platform.billable_count,
                "ready": self.platform.ready_count(now),
                "queued_batches": self.platform.queued_batches,
                "max_bs": float(self.policy.max_bs),
                "proxy_queue": self.policy.stats(now).get("queue_len", 0),
            }
        )
        if now < self.duration + self.drain_grace:
            self.events.push(now + self.sample_interval, self._on_sample)

    # ------------------------------------------------------------------ run
    def run(self) -> SimResult:
        first = self.arrivals.next_arrival(0.0, self.rng)
        if first is not None:
            self.events.push(first, self._on_arrival)
        self.events.push(0.0, self._on_sample)
        self.platform.start(0.0)
        if self.warmup > 0:
            self.events.push(self.warmup, self.platform.reset_billing)

        hard_stop = self._drive()
        self.platform.finalize(min(self.now, hard_stop))
        return self._result()

    def _result(self) -> SimResult:
        done = [r for r in self.completed if r.arrival_time >= self.warmup]
        e2e = np.asarray([r.e2e_latency for r in done], dtype=np.float64)
        arr = np.asarray([r.arrival_time for r in done], dtype=np.float64)
        viol = float(np.mean(e2e > self.sla.slo_target)) if len(e2e) else 0.0
        pstats = self.policy.stats(self.now)
        billing_window = max(self.now, self.duration) - self.warmup
        summary = {
            "completed": float(len(e2e)),
            "violation_rate": viol,
            "violation_pct": 100.0 * viol,
            "avg_containers": self.platform.avg_containers(billing_window),
            "peak_containers": float(self.platform.peak_containers),
            "avg_batch_size": pstats.get("avg_batch_size", 0.0),
            "p50": float(np.percentile(e2e, 50)) if len(e2e) else math.nan,
            "p95": float(np.percentile(e2e, 95)) if len(e2e) else math.nan,
            "p99": float(np.percentile(e2e, 99)) if len(e2e) else math.nan,
            "mean_latency": float(e2e.mean()) if len(e2e) else math.nan,
            "cold_starts": float(self.platform.cold_starts),
            "failed_attempts": float(self.platform.failed_attempts),
            "hedged_dispatches": float(self.platform.hedged_dispatches),
            "throughput": float(len(e2e)) / max(self.now, 1e-9),
        }
        # conservation ledger: every submitted batch must be completed or
        # still accounted for (queued/in-flight); lost and duplicate must
        # stay 0 in every run, faults or not
        cons = self.platform.conservation()
        summary.update(
            {
                "submitted_batches": float(cons["submitted_batches"]),
                "completed_batches": float(cons["completed_batches"]),
                "outstanding_batches": float(cons["outstanding_batches"]),
                "lost_batches": float(cons["lost_batches"]),
                "duplicate_completions": float(cons["duplicate_completions"]),
                "requeued_batches": float(cons["requeued_batches"]),
                "cancelled_attempts": float(cons["cancelled_attempts"]),
            }
        )
        timeline = {
            k: np.asarray([s[k] for s in self._samples], dtype=np.float64)
            for k in (self._samples[0].keys() if self._samples else [])
        }
        return SimResult(
            summary=summary,
            e2e_latencies=e2e,
            arrival_times=arr,
            timeline=timeline,
            policy_stats={k: v for k, v in pstats.items() if isinstance(v, (int, float))},
        )


def run_simulation(**kwargs) -> SimResult:
    """Convenience wrapper: ``run_simulation(policy=..., sla=..., ...)``."""
    return Simulator(**kwargs).run()


# ---------------------------------------------------------------------------
# Multi-endpoint scenario layer (beyond paper)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class EndpointSpec:
    """Everything one endpoint needs in a multi-endpoint scenario.

    ``platform`` names a shared-fleet group: endpoints with the same key
    run on one :class:`ServerlessPlatform` (multi-model serving); ``None``
    gives the endpoint a dedicated fleet. ``platform_config`` is taken from
    the first group member that sets one.
    """

    policy: str
    sla: SLAConfig
    workload: LatencyModel
    arrivals: ArrivalProcess
    policy_kwargs: Optional[dict] = None
    platform: Optional[str] = None
    platform_config: Optional[PlatformConfig] = None


@dataclasses.dataclass
class MultiSimResult:
    summary: Dict[str, float]                    # fleet-level aggregate
    endpoints: Dict[str, Dict[str, float]]       # per-endpoint summaries
    e2e_latencies: Dict[str, np.ndarray]         # per-endpoint latencies
    frontend_stats: dict


class MultiEndpointSimulator(_EventLoopDriver):
    """Drives one :class:`ProxyFrontend` over N endpoints in one event loop.

    Each endpoint has its own arrival process, SLA, policy, and (dedicated
    or shared) platform; the frontend merges every policy's timer into one
    clock, exactly as a single proxy process would in production.
    """

    def __init__(
        self,
        endpoints: Dict[str, EndpointSpec],
        *,
        duration: float = 600.0,
        warmup: float = 0.0,
        drain_grace: float = 120.0,
        seed: int = 0,
    ) -> None:
        if not endpoints:
            raise ValueError("need at least one endpoint")
        self.specs = dict(endpoints)
        self.duration = duration
        self.warmup = warmup
        self.drain_grace = drain_grace
        self.rng = np.random.default_rng(seed)
        self.events = EventQueue()
        self.now = 0.0

        # platform groups: shared key → one fleet; None → dedicated fleet
        groups: Dict[str, List[str]] = {}
        for name, spec in self.specs.items():
            key = spec.platform if spec.platform is not None else f"dedicated:{name}"
            groups.setdefault(key, []).append(name)
        self.platforms: Dict[str, ServerlessPlatform] = {}
        self._platform_of: Dict[str, str] = {}
        for key, members in groups.items():
            if len(members) == 1:
                latency: LatencyModel = self.specs[members[0]].workload
            else:
                latency = EndpointRoutedLatency(
                    {m: self.specs[m].workload for m in members}
                )
            pc = next(
                (self.specs[m].platform_config for m in members
                 if self.specs[m].platform_config is not None),
                None,
            )
            self.platforms[key] = ServerlessPlatform(
                config=pc or PlatformConfig(),
                latency_model=latency,
                events=self.events,
                rng=self.rng,
                on_batch_done=self._on_batch_done,
            )
            for m in members:
                self._platform_of[m] = key

        self.frontend = ProxyFrontend()
        for name, spec in self.specs.items():
            plat = self.platforms[self._platform_of[name]]
            self.frontend.add_endpoint(
                name,
                sla=spec.sla,
                dispatch_fn=lambda batch, _p=plat: _p.submit(batch, self.now),
                policy=spec.policy,
                policy_kwargs=spec.policy_kwargs,
            )

        self.completed: Dict[str, List[Request]] = {n: [] for n in self.specs}
        self._timer_scheduled_at: Optional[float] = None

    # --------------------------------------------------------------- wiring
    def _control(self):
        return self.frontend

    def _on_batch_done(self, batch: Batch, upstream_latency: float, now: float) -> None:
        self.frontend.on_response(batch, upstream_latency, now)
        for r in batch.requests:
            self.completed[batch.endpoint].append(r)
        self._reschedule_policy_timer()

    def _on_arrival(self, name: str, now: float) -> None:
        req = Request(arrival_time=now, endpoint=name)
        self.frontend.on_request(req, now)
        nxt = self.specs[name].arrivals.next_arrival(now, self.rng)
        if nxt is not None:
            self.events.push(nxt, lambda t, _n=name: self._on_arrival(_n, t))
        self._reschedule_policy_timer()

    # ------------------------------------------------------------------ run
    def run(self) -> MultiSimResult:
        for name, spec in self.specs.items():
            first = spec.arrivals.next_arrival(0.0, self.rng)
            if first is not None:
                self.events.push(first, lambda t, _n=name: self._on_arrival(_n, t))
        for plat in self.platforms.values():
            plat.start(0.0)
            if self.warmup > 0:
                self.events.push(self.warmup, plat.reset_billing)

        hard_stop = self._drive()
        for plat in self.platforms.values():
            plat.finalize(min(self.now, hard_stop))
        return self._result()

    def _result(self) -> MultiSimResult:
        billing_window = max(self.now, self.duration) - self.warmup
        fstats = self.frontend.stats(self.now)
        endpoints: Dict[str, Dict[str, float]] = {}
        latencies: Dict[str, np.ndarray] = {}
        for name, spec in self.specs.items():
            done = [r for r in self.completed[name] if r.arrival_time >= self.warmup]
            e2e = np.asarray([r.e2e_latency for r in done], dtype=np.float64)
            latencies[name] = e2e
            viol = float(np.mean(e2e > spec.sla.slo_target)) if len(e2e) else 0.0
            ep_stats = fstats["endpoints"][name]
            endpoints[name] = {
                "completed": float(len(e2e)),
                "slo_target": spec.sla.slo_target,
                "violation_rate": viol,
                "violation_pct": 100.0 * viol,
                "avg_batch_size": ep_stats.get("avg_batch_size", 0.0),
                "max_bs": float(ep_stats.get("max_bs", 1)),
                "p50": float(np.percentile(e2e, 50)) if len(e2e) else math.nan,
                "p95": float(np.percentile(e2e, 95)) if len(e2e) else math.nan,
                "mean_latency": float(e2e.mean()) if len(e2e) else math.nan,
            }
        total_containers = sum(
            p.avg_containers(billing_window) for p in self.platforms.values()
        )
        all_completed = sum(s["completed"] for s in endpoints.values())
        # fleet violation rate weighted by each endpoint's completed count
        agg_viol = (
            sum(s["violation_rate"] * s["completed"] for s in endpoints.values())
            / all_completed
            if all_completed
            else 0.0
        )
        summary = {
            "completed": all_completed,
            "violation_rate": agg_viol,
            "violation_pct": 100.0 * agg_viol,
            "avg_containers": total_containers,
            "peak_containers": float(
                sum(p.peak_containers for p in self.platforms.values())
            ),
            "cold_starts": float(sum(p.cold_starts for p in self.platforms.values())),
            "n_platforms": float(len(self.platforms)),
            "n_endpoints": float(len(self.specs)),
        }
        # fleet-wide conservation ledger (summed over every platform)
        cons = [p.conservation() for p in self.platforms.values()]
        for key in (
            "submitted_batches",
            "completed_batches",
            "outstanding_batches",
            "lost_batches",
            "duplicate_completions",
            "requeued_batches",
            "cancelled_attempts",
        ):
            summary[key] = float(sum(c[key] for c in cons))
        return MultiSimResult(
            summary=summary,
            endpoints=endpoints,
            e2e_latencies=latencies,
            frontend_stats=fstats,
        )


def run_multi_simulation(endpoints: Dict[str, EndpointSpec], **kwargs) -> MultiSimResult:
    """Convenience wrapper: ``run_multi_simulation({"a": EndpointSpec(...)})``."""
    return MultiEndpointSimulator(endpoints, **kwargs).run()
