"""End-to-end discrete-event simulation: arrivals → policy → platform.

Reproduces the paper's experimental pipeline (§3): a Poisson client
(optionally trace-modulated) sends requests to the front-end policy
(MLProxy or a baseline); the policy dispatches batches to the simulated
Knative platform; completions flow back through the policy's monitor.

Outputs match the paper's reporting: average container count (cost),
SLO-violation percentage, average batch size (Table 3), the CCDF of
response times (Fig. 6) and time series of P95 / containers / miss rate /
Max_BS (Fig. 7).
"""
from __future__ import annotations

import collections
import dataclasses
import math
from typing import Dict, List, Optional

import numpy as np

from repro.core import ProxyConfig, SLAConfig
from repro.core.policies import make_policy
from repro.core.request import Batch, Request
from repro.serverless.latency import LatencyModel
from repro.serverless.platform import PlatformConfig, ServerlessPlatform
from repro.simulation.arrivals import ArrivalProcess
from repro.simulation.events import EventQueue


@dataclasses.dataclass
class SimResult:
    summary: Dict[str, float]
    e2e_latencies: np.ndarray  # seconds, one per completed request
    arrival_times: np.ndarray
    timeline: Dict[str, np.ndarray]  # sampled time series
    policy_stats: Dict[str, float]

    def ccdf(self):
        """Return (latency_sorted, ccdf) for Fig.6-style plots."""
        lat = np.sort(self.e2e_latencies)
        n = len(lat)
        if n == 0:
            return lat, lat
        ccdf = 1.0 - (np.arange(1, n + 1) / n)
        return lat, ccdf


class Simulator:
    def __init__(
        self,
        *,
        policy: str,
        sla: SLAConfig,
        workload: LatencyModel,
        arrivals: ArrivalProcess,
        platform_config: Optional[PlatformConfig] = None,
        policy_kwargs: Optional[dict] = None,
        duration: float = 600.0,
        warmup: float = 0.0,
        drain_grace: float = 120.0,
        sample_interval: float = 5.0,
        p95_window: float = 60.0,
        seed: int = 0,
    ) -> None:
        self.sla = sla
        self.workload = workload
        self.arrivals = arrivals
        self.duration = duration
        self.warmup = warmup
        self.drain_grace = drain_grace
        self.sample_interval = sample_interval
        self.p95_window = p95_window
        self.rng = np.random.default_rng(seed)
        self.events = EventQueue()
        self.now = 0.0

        self.platform = ServerlessPlatform(
            config=platform_config or PlatformConfig(),
            latency_model=workload,
            events=self.events,
            rng=self.rng,
            on_batch_done=self._on_batch_done,
        )
        self.policy = make_policy(
            policy, sla, self._dispatch, **(policy_kwargs or {})
        )

        self.completed: List[Request] = []
        self._recent: collections.deque = collections.deque()  # (t_done, e2e)
        self._timer_scheduled_at: Optional[float] = None
        self._samples: List[dict] = []

    # --------------------------------------------------------------- wiring
    def _dispatch(self, batch: Batch) -> None:
        self.platform.submit(batch, self.now)

    def _on_batch_done(self, batch: Batch, upstream_latency: float, now: float) -> None:
        self.policy.on_response(batch, upstream_latency, now)
        for r in batch.requests:
            self.completed.append(r)
            self._recent.append((now, r.e2e_latency))
        self._reschedule_policy_timer()

    def _on_arrival(self, now: float) -> None:
        req = Request(arrival_time=now)
        self.policy.on_request(req, now)
        nxt = self.arrivals.next_arrival(now, self.rng)
        if nxt is not None:
            self.events.push(nxt, self._on_arrival)
        self._reschedule_policy_timer()

    def _on_policy_timer(self, now: float) -> None:
        self._timer_scheduled_at = None
        self.policy.on_timer(now)
        self._reschedule_policy_timer(min_time=now + 1e-6)

    def _reschedule_policy_timer(self, min_time: float = 0.0) -> None:
        t = self.policy.next_event_time(self.now)
        if t is None:
            return
        # min_time guards against zero-progress loops when a policy keeps
        # requesting the instant a timer just served
        t = max(t, self.now, min_time)
        if self._timer_scheduled_at is None or t < self._timer_scheduled_at - 1e-12:
            self._timer_scheduled_at = t
            self.events.push(t, self._on_policy_timer)

    # --------------------------------------------------------------- metrics
    def _on_sample(self, now: float) -> None:
        cutoff = now - self.p95_window
        while self._recent and self._recent[0][0] < cutoff:
            self._recent.popleft()
        lats = [l for (_, l) in self._recent]
        p95 = float(np.percentile(lats, 95)) if lats else math.nan
        miss = (
            sum(1 for l in lats if l > self.sla.slo_target) / len(lats)
            if lats
            else math.nan
        )
        self._samples.append(
            {
                "t": now,
                "p95": p95,
                "miss_rate": miss,
                "containers": self.platform._billable_count(),
                "ready": self.platform._ready_count(now),
                "queued_batches": len(self.platform.pending),
                "max_bs": float(self.policy.max_bs),
                "proxy_queue": self.policy.stats(now).get("queue_len", 0),
            }
        )
        if now < self.duration + self.drain_grace:
            self.events.push(now + self.sample_interval, self._on_sample)

    # ------------------------------------------------------------------ run
    def run(self) -> SimResult:
        first = self.arrivals.next_arrival(0.0, self.rng)
        if first is not None:
            self.events.push(first, self._on_arrival)
        self.events.push(0.0, self._on_sample)
        self.platform.start(0.0)
        if self.warmup > 0:
            self.events.push(self.warmup, self.platform.reset_billing)

        hard_stop = self.duration + self.drain_grace
        flushed = False
        while self.events:
            t, fn = self.events.pop()
            if t > hard_stop:
                break
            self.now = t
            if not flushed and t >= self.duration:
                self.policy.flush(self.now)
                flushed = True
            fn(t)
        if not flushed:
            self.policy.flush(self.now)
        # drain remaining completions
        while self.events:
            t, fn = self.events.pop()
            if t > hard_stop:
                break
            self.now = t
            fn(t)
        self.platform.finalize(min(self.now, hard_stop))
        return self._result()

    def _result(self) -> SimResult:
        done = [r for r in self.completed if r.arrival_time >= self.warmup]
        e2e = np.asarray([r.e2e_latency for r in done], dtype=np.float64)
        arr = np.asarray([r.arrival_time for r in done], dtype=np.float64)
        viol = float(np.mean(e2e > self.sla.slo_target)) if len(e2e) else 0.0
        pstats = self.policy.stats(self.now)
        billing_window = max(self.now, self.duration) - self.warmup
        summary = {
            "completed": float(len(e2e)),
            "violation_rate": viol,
            "violation_pct": 100.0 * viol,
            "avg_containers": self.platform.avg_containers(billing_window),
            "peak_containers": float(self.platform.peak_containers),
            "avg_batch_size": pstats.get("avg_batch_size", 0.0),
            "p50": float(np.percentile(e2e, 50)) if len(e2e) else math.nan,
            "p95": float(np.percentile(e2e, 95)) if len(e2e) else math.nan,
            "p99": float(np.percentile(e2e, 99)) if len(e2e) else math.nan,
            "mean_latency": float(e2e.mean()) if len(e2e) else math.nan,
            "cold_starts": float(self.platform.cold_starts),
            "failed_attempts": float(self.platform.failed_attempts),
            "hedged_dispatches": float(self.platform.hedged_dispatches),
            "throughput": float(len(e2e)) / max(self.now, 1e-9),
        }
        timeline = {
            k: np.asarray([s[k] for s in self._samples], dtype=np.float64)
            for k in (self._samples[0].keys() if self._samples else [])
        }
        return SimResult(
            summary=summary,
            e2e_latencies=e2e,
            arrival_times=arr,
            timeline=timeline,
            policy_stats={k: v for k, v in pstats.items() if isinstance(v, (int, float))},
        )


def run_simulation(**kwargs) -> SimResult:
    """Convenience wrapper: ``run_simulation(policy=..., sla=..., ...)``."""
    return Simulator(**kwargs).run()
