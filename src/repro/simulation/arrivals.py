"""Arrival processes (the paper generates clients with a Poisson process
modulated by real-world traces; §3.1, §3.5)."""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.simulation.traces import Trace


class ArrivalProcess:
    """Protocol: next arrival strictly after ``now``, or None when done."""

    def next_arrival(self, now: float, rng: np.random.Generator) -> Optional[float]:
        raise NotImplementedError


@dataclasses.dataclass
class PoissonProcess(ArrivalProcess):
    """Homogeneous Poisson with rate ``rate`` (req/s) over [0, duration)."""

    rate: float
    duration: float

    def next_arrival(self, now: float, rng: np.random.Generator) -> Optional[float]:
        if self.rate <= 0:
            return None
        t = now + rng.exponential(1.0 / self.rate)
        return t if t < self.duration else None


@dataclasses.dataclass
class DeterministicProcess(ArrivalProcess):
    """Fixed inter-arrival gap (tests and worst-case analyses)."""

    gap: float
    duration: float

    def next_arrival(self, now: float, rng: np.random.Generator) -> Optional[float]:
        t = now + self.gap
        return t if t < self.duration else None


@dataclasses.dataclass
class TraceModulatedPoisson(ArrivalProcess):
    """Non-homogeneous Poisson via thinning (Lewis & Shedler, 1979).

    λ(t) comes from a :class:`Trace`; proposals are generated at λ_max and
    accepted with probability λ(t)/λ_max — exact for piecewise-constant
    rate profiles and O(1) per proposal.
    """

    trace: Trace

    def next_arrival(self, now: float, rng: np.random.Generator) -> Optional[float]:
        lam_max = self.trace.max_rate
        if lam_max <= 0:
            return None
        t = now
        end = float(self.trace.times[-1])
        while True:
            t = t + rng.exponential(1.0 / lam_max)
            if t >= end:
                return None
            if rng.random() * lam_max <= self.trace.rate_at(t):
                return t


@dataclasses.dataclass
class MMPP2(ArrivalProcess):
    """2-state Markov-modulated Poisson process (bursty-load stress tests).

    State 0: rate ``rate_lo``; state 1: rate ``rate_hi``; exponential
    sojourn times with means ``mean_lo`` / ``mean_hi``.
    """

    rate_lo: float
    rate_hi: float
    mean_lo: float
    mean_hi: float
    duration: float
    _state: int = 0
    _switch_at: Optional[float] = None

    def next_arrival(self, now: float, rng: np.random.Generator) -> Optional[float]:
        t = now
        while True:
            if self._switch_at is None:
                mean = self.mean_lo if self._state == 0 else self.mean_hi
                self._switch_at = t + rng.exponential(mean)
            rate = self.rate_lo if self._state == 0 else self.rate_hi
            if rate <= 0:
                t = self._switch_at
            else:
                cand = t + rng.exponential(1.0 / rate)
                if cand < self._switch_at:
                    return cand if cand < self.duration else None
                t = self._switch_at
            if t >= self.duration:
                return None
            self._state ^= 1
            self._switch_at = None
