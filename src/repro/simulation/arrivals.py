"""Arrival processes (the paper generates clients with a Poisson process
modulated by real-world traces; §3.1, §3.5).

Two APIs per process:

* ``next_arrival(now, rng)`` — the original scalar protocol: the next
  arrival strictly after ``now``, or ``None`` when the process is done.
* ``next_arrivals(now, rng, horizon)`` — the vectorized protocol: every
  arrival in the half-open window ``(now, now + horizon]`` as one numpy
  array, presampled in blocks. Callers sweep contiguous windows
  (successive calls advance ``now`` by exactly ``horizon``); processes may
  keep internal state across windows (e.g. the MMPP2 modulating chain),
  which :meth:`reset` clears before a fresh run.

The simulator feeds its event loop from ``next_arrivals`` through a cursor
(see ``repro.simulation.simulator._ArrivalPump``), which replaces one RNG
call + one closure per request with one amortized numpy block draw.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.simulation.traces import Trace

_EMPTY = np.empty(0, dtype=np.float64)


def _poisson_window(start: float, end: float, rate: float,
                    rng: np.random.Generator) -> np.ndarray:
    """All homogeneous-Poisson arrivals in ``(start, end]`` at ``rate``.

    Draws exponential gaps in blocks sized to the expected count; the
    overshoot draws past ``end`` are discarded (memorylessness makes the
    restart at the next window boundary exact).
    """
    if rate <= 0 or end <= start:
        return _EMPTY
    chunks = []
    t = start
    while True:
        n = max(16, int(rate * (end - t) * 1.2) + 8)
        times = t + np.cumsum(rng.exponential(1.0 / rate, n))
        last = float(times[-1])
        if last > end:
            chunks.append(times[: int(np.searchsorted(times, end, side="right"))])
            break
        chunks.append(times)
        if last == end:
            break
        t = last
    return chunks[0] if len(chunks) == 1 else np.concatenate(chunks)


class ArrivalProcess:
    """Protocol: scalar ``next_arrival`` plus vectorized ``next_arrivals``."""

    def next_arrival(self, now: float, rng: np.random.Generator) -> Optional[float]:
        raise NotImplementedError

    def reset(self) -> None:
        """Clear internal window-sweep state before a fresh run.

        Subclasses with their own state (e.g. :class:`MMPP2`) must call
        ``super().reset()`` or clear everything themselves.
        """
        self._pending = None  # overshoot buffer of the generic fallback

    def next_arrivals(self, now: float, rng: np.random.Generator,
                      horizon: float) -> np.ndarray:
        """Every arrival in ``(now, now + horizon]`` as a float64 array.

        Generic fallback loops the scalar API and buffers the one draw
        that overshoots the window so no arrival is lost between windows;
        subclasses override with true block sampling.
        """
        end = now + horizon
        out = []
        t = getattr(self, "_pending", None)
        if t is not None:
            self._pending = None
            if t > end:
                self._pending = t
                return _EMPTY
            out.append(t)
        else:
            t = now
        while True:
            t = self.next_arrival(out[-1] if out else t, rng)
            if t is None:
                break
            if t > end:
                self._pending = t  # carried into the next window
                break
            out.append(t)
        return np.asarray(out, dtype=np.float64)


@dataclasses.dataclass(slots=True)
class PoissonProcess(ArrivalProcess):
    """Homogeneous Poisson with rate ``rate`` (req/s) over [0, duration)."""

    rate: float
    duration: float

    def next_arrival(self, now: float, rng: np.random.Generator) -> Optional[float]:
        if self.rate <= 0:
            return None
        t = now + rng.exponential(1.0 / self.rate)
        return t if t < self.duration else None

    def next_arrivals(self, now: float, rng: np.random.Generator,
                      horizon: float) -> np.ndarray:
        return _poisson_window(now, min(now + horizon, self.duration),
                               self.rate, rng)


@dataclasses.dataclass(slots=True)
class DeterministicProcess(ArrivalProcess):
    """Fixed inter-arrival gap (tests and worst-case analyses)."""

    gap: float
    duration: float

    def next_arrival(self, now: float, rng: np.random.Generator) -> Optional[float]:
        t = now + self.gap
        return t if t < self.duration else None

    def next_arrivals(self, now: float, rng: np.random.Generator,
                      horizon: float) -> np.ndarray:
        # Arrivals sit on the exact lattice k*gap (k >= 1), computed
        # directly so the sweep is stateless. This matches a scalar chain
        # started at t=0 except at the duration boundary: when duration is
        # an exact multiple of gap, the chain's accumulated rounding can
        # land its last arrival a few ulps below duration, while the
        # lattice correctly excludes k*gap == duration.
        end = min(now + horizon, self.duration)
        k0 = int(np.floor(now / self.gap)) + 1
        while k0 * self.gap <= now:  # strictly after `now`
            k0 += 1
        k1 = int(np.floor(end / self.gap))
        while k1 * self.gap > end:
            k1 -= 1
        if k1 * self.gap >= self.duration:  # duration boundary is exclusive
            k1 -= 1
        if k1 < k0:
            return _EMPTY
        return np.arange(k0, k1 + 1, dtype=np.float64) * self.gap


@dataclasses.dataclass(slots=True)
class TraceModulatedPoisson(ArrivalProcess):
    """Non-homogeneous Poisson via thinning (Lewis & Shedler, 1979).

    λ(t) comes from a :class:`Trace`; proposals are generated at λ_max and
    accepted with probability λ(t)/λ_max — exact for piecewise-constant
    rate profiles and O(1) per proposal. The vectorized path draws the
    proposal gaps and acceptance uniforms in paired blocks and evaluates
    λ(t) for the whole block with one searchsorted.
    """

    trace: Trace

    def next_arrival(self, now: float, rng: np.random.Generator) -> Optional[float]:
        lam_max = self.trace.max_rate
        if lam_max <= 0:
            return None
        t = now
        end = float(self.trace.times[-1])
        while True:
            t = t + rng.exponential(1.0 / lam_max)
            if t >= end:
                return None
            if rng.random() * lam_max <= self.trace.rate_at(t):
                return t

    def next_arrivals(self, now: float, rng: np.random.Generator,
                      horizon: float) -> np.ndarray:
        lam_max = self.trace.max_rate
        if lam_max <= 0:
            return _EMPTY
        end = min(now + horizon, float(self.trace.times[-1]))
        accepted = []
        t = now
        while t < end:
            n = max(16, int(lam_max * (end - t) * 1.2) + 8)
            props = t + np.cumsum(rng.exponential(1.0 / lam_max, n))
            u = rng.random(n)  # paired acceptance draws, same block order
            cut = int(np.searchsorted(props, end, side="right"))
            if cut:
                within = props[:cut]
                keep = u[:cut] * lam_max <= self.trace.rate_at_many(within)
                accepted.append(within[keep])
            last = float(props[-1])
            if last >= end:
                break
            t = last
        if not accepted:
            return _EMPTY
        return accepted[0] if len(accepted) == 1 else np.concatenate(accepted)


@dataclasses.dataclass(slots=True)
class Schedule(ArrivalProcess):
    """Replays an explicit, pre-sampled array of arrival times.

    The shared-workload primitive of the sim↔live bridge: sample any
    stochastic process ONCE with :func:`sample_schedule`, then replay the
    identical arrival instants through the discrete-event simulator and
    the wall-clock runtime (``repro.runtime``), so both worlds serve the
    same trace. Stateless and RNG-free — replaying never consumes draws.
    """

    times: np.ndarray

    def __post_init__(self) -> None:
        self.times = np.sort(np.asarray(self.times, dtype=np.float64))

    @property
    def duration(self) -> float:
        return float(self.times[-1]) if len(self.times) else 0.0

    def next_arrival(self, now: float, rng: np.random.Generator) -> Optional[float]:
        i = int(np.searchsorted(self.times, now, side="right"))
        return float(self.times[i]) if i < len(self.times) else None

    def next_arrivals(self, now: float, rng: np.random.Generator,
                      horizon: float) -> np.ndarray:
        lo = int(np.searchsorted(self.times, now, side="right"))
        hi = int(np.searchsorted(self.times, now + horizon, side="right"))
        return self.times[lo:hi].copy()


def sample_schedule(process: ArrivalProcess, rng, duration: float,
                    horizon: float = 64.0) -> np.ndarray:
    """Materialize every arrival of ``process`` over ``[0, duration)``.

    ``rng`` is a seed or a ``numpy`` Generator. Sweeps contiguous
    fixed-``horizon`` windows of the vectorized API; the draw follows the
    process's distribution exactly, but the concrete instants for a given
    seed differ from a ``Simulator`` run sampling the live process (its
    arrival pump uses adaptive windows, and window boundaries change which
    overshoot draws are discarded). To put the *identical* workload in
    both worlds, sample once with this function and hand the same
    :class:`Schedule` to both — which is what the parity bench does.
    """
    if not isinstance(rng, np.random.Generator):
        rng = np.random.default_rng(rng)
    process.reset()
    chunks = []
    t = 0.0
    while t < duration:
        h = min(horizon, duration - t)
        block = process.next_arrivals(t, rng, h)
        if len(block):
            chunks.append(block)
        t += h
    if not chunks:
        return _EMPTY.copy()
    out = chunks[0] if len(chunks) == 1 else np.concatenate(chunks)
    return out[out < duration]


@dataclasses.dataclass(slots=True)
class MMPP2(ArrivalProcess):
    """2-state Markov-modulated Poisson process (bursty-load stress tests).

    State 0: rate ``rate_lo``; state 1: rate ``rate_hi``; exponential
    sojourn times with means ``mean_lo`` / ``mean_hi``. The modulating
    chain is internal state that persists across windows; :meth:`reset`
    rewinds it for a fresh run.
    """

    rate_lo: float
    rate_hi: float
    mean_lo: float
    mean_hi: float
    duration: float
    _state: int = 0
    _switch_at: Optional[float] = None

    def reset(self) -> None:
        self._state = 0
        self._switch_at = None

    def next_arrival(self, now: float, rng: np.random.Generator) -> Optional[float]:
        t = now
        while True:
            if self._switch_at is None:
                mean = self.mean_lo if self._state == 0 else self.mean_hi
                self._switch_at = t + rng.exponential(mean)
            rate = self.rate_lo if self._state == 0 else self.rate_hi
            if rate <= 0:
                t = self._switch_at
            else:
                cand = t + rng.exponential(1.0 / rate)
                if cand < self._switch_at:
                    return cand if cand < self.duration else None
                t = self._switch_at
            if t >= self.duration:
                return None
            self._state ^= 1
            self._switch_at = None

    def next_arrivals(self, now: float, rng: np.random.Generator,
                      horizon: float) -> np.ndarray:
        end = min(now + horizon, self.duration)
        out = []
        t = now
        while t < end:
            if self._switch_at is None:
                mean = self.mean_lo if self._state == 0 else self.mean_hi
                self._switch_at = t + rng.exponential(mean)
            rate = self.rate_lo if self._state == 0 else self.rate_hi
            seg_end = min(self._switch_at, end)
            if rate > 0:
                seg = _poisson_window(t, seg_end, rate, rng)
                if len(seg):
                    out.append(seg)
            if self._switch_at <= end:
                t = self._switch_at
                self._state ^= 1
                self._switch_at = None
            else:
                t = end
        if not out:
            return _EMPTY
        return out[0] if len(out) == 1 else np.concatenate(out)
