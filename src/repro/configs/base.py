"""Model configuration system.

One :class:`ModelConfig` per assigned architecture lives in
``repro/configs/<arch>.py``; the registry in ``repro.configs`` maps
``--arch`` ids to them. ``reduced()`` produces a family-preserving small
config for CPU smoke tests; full configs are only ever lowered via the
dry-run (ShapeDtypeStruct, no allocation).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    """One assigned (input-shape) cell."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # 'train' | 'prefill' | 'decode'


SHAPES: Tuple[ShapeCell, ...] = (
    ShapeCell("train_4k", 4096, 256, "train"),
    ShapeCell("prefill_32k", 32768, 32, "prefill"),
    ShapeCell("decode_32k", 32768, 128, "decode"),
    ShapeCell("long_500k", 524288, 1, "decode"),
)

SHAPES_BY_NAME = {s.name: s for s in SHAPES}


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # 'dense' | 'moe' | 'hybrid' | 'ssm' | 'encdec' | 'vlm'
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None
    activation: str = "silu"
    norm: str = "rmsnorm"
    qkv_bias: bool = False
    mlp_bias: bool = False
    tie_embeddings: bool = False
    rope_theta: float = 1e4
    max_seq_len: int = 32768
    # --- MoE ---
    num_experts: int = 0
    num_experts_per_tok: int = 0
    expert_d_ff: int = 0
    moe_shared_ffn: bool = False  # dense (shared-expert) FFN alongside routed
    capacity_factor: float = 1.25
    # --- SSM / hybrid ---
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_chunk: int = 128
    attn_every: int = 0  # zamba2: shared attention before every Nth block
    mlstm_per_slstm: int = 7  # xlstm block ratio
    # --- enc-dec ---
    encoder_layers: int = 0
    cross_attention: bool = False
    # --- modality frontend stubs ---
    embed_inputs: bool = False  # training inputs are embeddings, not tokens
    frontend_seq: int = 0  # encoder memory length supplied by the stub
    # --- numerics / training ---
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    remat: bool = True
    scan_layers: bool = True
    # --- serving ---
    attn_q_chunk: int = 512
    use_pallas: bool = False  # TPU: route attention/SSD through Pallas kernels

    # ------------------------------------------------------------------ api
    @property
    def hd(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.num_heads

    @property
    def dtype(self):
        return jnp.dtype(self.param_dtype)

    @property
    def cdtype(self):
        return jnp.dtype(self.compute_dtype)

    def supports_shape(self, shape: ShapeCell) -> bool:
        """long_500k needs a sub-quadratic mixer (DESIGN.md §shape-skips)."""
        if shape.name == "long_500k":
            return self.family in ("hybrid", "ssm")
        return True

    def skip_reason(self, shape: ShapeCell) -> Optional[str]:
        if self.supports_shape(shape):
            return None
        return "full-attention@500k"

    def param_count(self) -> int:
        """Approximate parameter count (embedding + layers), for roofline."""
        d, f, v = self.d_model, self.d_ff, self.vocab_size
        hd, hq, hkv = self.hd, self.num_heads, self.num_kv_heads
        attn = d * hd * (hq + 2 * hkv) + hq * hd * d
        if self.family in ("dense", "vlm"):
            ffn = d * f * (3 if self.activation == "silu" else 2)
            per_layer = attn + ffn
            layers = self.num_layers * per_layer
        elif self.family == "moe":
            gated = 3 if self.activation == "silu" else 2
            routed = self.num_experts * d * self.expert_d_ff * gated
            shared = d * f * gated if self.moe_shared_ffn else 0
            layers = self.num_layers * (attn + routed + shared + d * self.num_experts)
        elif self.family == "hybrid":
            d_inner = 2 * d
            mamba = d * (2 * d_inner + 2 * self.ssm_state + d_inner // self.ssm_head_dim)
            mamba += d_inner * d
            layers = self.num_layers * mamba + attn  # one shared attn block
        elif self.family == "ssm":
            d_inner = 2 * d
            hd_i = d_inner // self.num_heads
            mlstm = d * 2 * d_inner + 3 * self.num_heads * hd_i * hd_i + d_inner * d
            slstm = 4 * d * d + self.num_heads * (d // self.num_heads) ** 2 * 4 + d * d
            n_s = self.num_layers // (self.mlstm_per_slstm + 1)
            layers = (self.num_layers - n_s) * mlstm + n_s * slstm
        elif self.family == "encdec":
            ffn = d * f * (3 if self.activation == "silu" else 2)
            enc = self.encoder_layers * (attn + ffn)
            dec = self.num_layers * (2 * attn + ffn)
            layers = enc + dec
        else:
            raise ValueError(self.family)
        embed = v * d * (1 if self.tie_embeddings else 2)
        return int(layers + embed)

    def active_param_count(self) -> int:
        """Activated params per token (== param_count for dense)."""
        if self.family != "moe":
            return self.param_count()
        d = self.d_model
        gated = 3 if self.activation == "silu" else 2
        hd, hq, hkv = self.hd, self.num_heads, self.num_kv_heads
        attn = d * hd * (hq + 2 * hkv) + hq * hd * d
        routed_active = self.num_experts_per_tok * d * self.expert_d_ff * gated
        shared = d * self.d_ff * gated if self.moe_shared_ffn else 0
        layers = self.num_layers * (attn + routed_active + shared + d * self.num_experts)
        embed = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        return int(layers + embed)

    def reduced(self) -> "ModelConfig":
        """Tiny same-family config for CPU smoke tests."""
        kw = dict(
            name=self.name + "-smoke",
            capacity_factor=8.0,  # drop-free at smoke scale → exact streaming
            num_layers=min(self.num_layers, 4 if self.family in ("hybrid", "ssm") else 2),
            d_model=64,
            num_heads=4,
            num_kv_heads=min(self.num_kv_heads, 2),
            head_dim=16,
            d_ff=96 if self.d_ff else 0,
            vocab_size=128,
            max_seq_len=64,
            param_dtype="float32",
            compute_dtype="float32",
            remat=False,
            attn_q_chunk=16,
            ssm_chunk=8,
            ssm_state=16 if self.ssm_state else 0,
            ssm_head_dim=16 if self.ssm_state else 64,
            num_experts=4 if self.num_experts else 0,
            num_experts_per_tok=min(self.num_experts_per_tok, 2),
            expert_d_ff=48 if self.expert_d_ff else 0,
            encoder_layers=2 if self.encoder_layers else 0,
            attn_every=2 if self.attn_every else 0,
            mlstm_per_slstm=min(self.mlstm_per_slstm, 3),
            frontend_seq=8 if self.frontend_seq else 0,
        )
        if self.family == "ssm":
            kw["num_layers"] = kw["mlstm_per_slstm"] + 1
        return dataclasses.replace(self, **kw)
