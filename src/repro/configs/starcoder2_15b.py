"""StarCoder2-15B. 40L d_model=6144 48H (GQA kv=4) d_ff=24576 vocab=49152;
GQA + RoPE, biases on attention/MLP, non-gated GELU, LayerNorm.
[arXiv:2402.19173; hf]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-15b",
    family="dense",
    num_layers=40,
    d_model=6144,
    num_heads=48,
    num_kv_heads=4,
    head_dim=128,
    d_ff=24576,
    vocab_size=49152,
    activation="gelu",
    norm="layernorm",
    qkv_bias=True,
    mlp_bias=True,
    rope_theta=1e5,
    max_seq_len=16384,
)
