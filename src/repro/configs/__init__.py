"""Architecture config registry: ``get_config("<arch-id>")``.

Ten assigned architectures (see DESIGN.md §3) plus the paper's own
serving workloads. Full configs are exercised only via the dry-run;
``get_config(arch).reduced()`` gives the CPU smoke-test variant.
"""
from repro.configs.base import ModelConfig, ShapeCell, SHAPES, SHAPES_BY_NAME  # noqa: F401

from repro.configs.llama4_scout_17b_a16e import CONFIG as _llama4
from repro.configs.kimi_k2_1t_a32b import CONFIG as _kimi
from repro.configs.starcoder2_15b import CONFIG as _starcoder2
from repro.configs.qwen2_0_5b import CONFIG as _qwen2
from repro.configs.nemotron_4_340b import CONFIG as _nemotron
from repro.configs.yi_34b import CONFIG as _yi
from repro.configs.zamba2_1_2b import CONFIG as _zamba2
from repro.configs.xlstm_1_3b import CONFIG as _xlstm
from repro.configs.seamless_m4t_large_v2 import CONFIG as _seamless
from repro.configs.internvl2_76b import CONFIG as _internvl

REGISTRY = {
    "llama4-scout-17b-a16e": _llama4,
    "kimi-k2-1t-a32b": _kimi,
    "starcoder2-15b": _starcoder2,
    "qwen2-0.5b": _qwen2,
    "nemotron-4-340b": _nemotron,
    "yi-34b": _yi,
    "zamba2-1.2b": _zamba2,
    "xlstm-1.3b": _xlstm,
    "seamless-m4t-large-v2": _seamless,
    "internvl2-76b": _internvl,
}

ARCH_IDS = tuple(REGISTRY)


def get_config(arch: str) -> ModelConfig:
    try:
        return REGISTRY[arch]
    except KeyError:
        raise KeyError(f"unknown arch {arch!r}; available: {sorted(REGISTRY)}") from None
