"""Llama-4 Scout 17B-active / 16-expert.

48L d_model=5120 40H (GQA kv=8) d_ff=8192 vocab=202048; MoE with 16 routed
experts, top-1 routing, plus a shared (dense) expert per layer — early
fusion multimodality is out of scope for the LM backbone cells.
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama4-scout-17b-a16e",
    family="moe",
    num_layers=48,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    head_dim=128,
    d_ff=8192,  # shared-expert / dense FFN width
    vocab_size=202048,
    activation="silu",
    norm="rmsnorm",
    num_experts=16,
    num_experts_per_tok=1,
    expert_d_ff=8192,
    moe_shared_ffn=True,
    rope_theta=5e5,
    max_seq_len=524288,
)
