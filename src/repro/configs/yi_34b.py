"""Yi-34B. 60L d_model=7168 56H (GQA kv=8) d_ff=20480 vocab=64000;
Llama-architecture GQA, SwiGLU, RMSNorm. [arXiv:2403.04652; hf]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="yi-34b",
    family="dense",
    num_layers=60,
    d_model=7168,
    num_heads=56,
    num_kv_heads=8,
    head_dim=128,
    d_ff=20480,
    vocab_size=64000,
    activation="silu",
    norm="rmsnorm",
    rope_theta=5e6,
    max_seq_len=200000,
)
