"""InternVL2-76B — LM backbone only (InternViT frontend is a STUB; the
training cell feeds precomputed patch+text embeddings). 80L d_model=8192
64H (GQA kv=8) d_ff=28672 vocab=128256, Llama-3-70B-shaped backbone.
[arXiv:2404.16821; unverified]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-76b",
    family="vlm",
    num_layers=80,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=28672,
    vocab_size=128256,
    activation="silu",
    norm="rmsnorm",
    embed_inputs=True,  # train cells consume stub embeddings
    rope_theta=5e5,
    max_seq_len=32768,
)
