"""Zamba2-1.2B. 38 Mamba-2 blocks (d_model=2048, ssm_state=64) with a
single shared attention(+FFN) block (32H, kv=32, d_ff=8192) applied before
every 6th Mamba block. Sub-quadratic → runs the long_500k cell.
[arXiv:2411.15242; hf]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b",
    family="hybrid",
    num_layers=38,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    head_dim=64,
    d_ff=8192,
    vocab_size=32000,
    activation="silu",
    norm="rmsnorm",
    ssm_state=64,
    ssm_head_dim=64,
    ssm_chunk=128,
    attn_every=6,
    rope_theta=1e4,
    max_seq_len=524288,
)
