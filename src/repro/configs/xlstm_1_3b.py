"""xLSTM-1.3B. 48 blocks (d_model=2048, 4 heads) in xLSTM[7:1] layout:
super-blocks of 7 mLSTM + 1 sLSTM. d_ff=0 — blocks carry their own
up/down projections. Sub-quadratic → runs the long_500k cell.
[arXiv:2405.04517; unverified]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-1.3b",
    family="ssm",
    num_layers=48,
    d_model=2048,
    num_heads=4,
    num_kv_heads=4,
    head_dim=512,
    d_ff=0,
    vocab_size=50304,
    activation="silu",
    norm="layernorm",
    mlstm_per_slstm=7,
    max_seq_len=524288,
)
