"""Kimi K2 — trillion-parameter MoE, 32B active.

61L d_model=7168 64H (GQA kv=8) d_ff=2048 (per-expert) vocab=163840;
384 routed experts, top-8, one shared expert. The released K2 uses MLA
attention and a dense first layer; this config follows the assigned table
(GQA kv=8, uniform MoE layers) — deviations noted in DESIGN.md.
[arXiv:2501.kimi2; unverified]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    num_layers=61,
    d_model=7168,
    num_heads=64,
    num_kv_heads=8,
    head_dim=112,
    d_ff=2048,  # shared-expert width
    vocab_size=163840,
    activation="silu",
    norm="rmsnorm",
    num_experts=384,
    num_experts_per_tok=8,
    expert_d_ff=2048,
    moe_shared_ffn=True,
    capacity_factor=1.25,
    rope_theta=5e4,
    max_seq_len=131072,
)
