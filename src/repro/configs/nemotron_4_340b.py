"""Nemotron-4 340B. 96L d_model=18432 96H (GQA kv=8) d_ff=73728
vocab=256000; squared-ReLU MLP (non-gated), LayerNorm, RoPE.
[arXiv:2402.16819; unverified]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="nemotron-4-340b",
    family="dense",
    num_layers=96,
    d_model=18432,
    num_heads=96,
    num_kv_heads=8,
    head_dim=192,
    d_ff=73728,
    vocab_size=256000,
    activation="relu2",
    norm="layernorm",
    rope_theta=1e4,
    max_seq_len=4096,
)
