"""SeamlessM4T-Large v2 (text/speech backbone). 24L encoder + 24L decoder,
d_model=1024 16H (kv=16) d_ff=8192 vocab=256206. The speech frontend is a
STUB: input_specs supplies precomputed frame embeddings (B, frames, D).
[arXiv:2308.11596; hf]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2",
    family="encdec",
    num_layers=24,  # decoder layers
    encoder_layers=24,
    cross_attention=True,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    head_dim=64,
    d_ff=8192,
    vocab_size=256206,
    activation="gelu",
    norm="layernorm",
    embed_inputs=True,
    frontend_seq=4096,  # stub speech frames fed to the encoder
    rope_theta=1e4,
    max_seq_len=32768,
)
