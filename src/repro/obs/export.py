"""Span reconstruction and exporters for tracer event streams.

Two artifacts per the observability plan:

- Chrome ``trace_event`` JSON (:func:`chrome_trace` /
  :func:`write_chrome_trace`): open in ``chrome://tracing`` or
  https://ui.perfetto.dev. Requests render as one lane per request
  (queue slice + service slice); batches render on their own lanes with
  instant markers for retries, hedges, faults and breaker waits.
- Flat per-request CSV (:func:`write_request_csv`): one row per request
  with the budget breakdown — queue wait (admission→batch formation),
  service (dispatch→resolution), retry overhead (sum of retry backoffs
  charged to the batch), breaker wait, and the terminal outcome.

Reconstruction is a single pass over the flat event tuples; no state is
kept in the hot path. All timestamps are whatever clock domain the
tracer saw (sim seconds or FakeClock seconds); Chrome expects
microseconds, so export multiplies by 1e6.
"""
from __future__ import annotations

import csv
import json
import os
from typing import Dict, List

from repro.obs.trace import (EV_BATCH, EV_DETAIL, EV_ENDPOINT, EV_KIND,
                             EV_REQ, EV_SIZE, EV_T, EV_VALUE, TraceTuple)

_TERMINAL_BATCH = ("completed", "timed_out", "failed")
_TERMINAL_REQ = ("expired", "shed", "rejected")
#: Kinds whose value slot carries the request's queue-entry arrival time
#: ("batched" carries a tuple of them and is unpacked separately).
_QUEUE_ANCHORED = ("expired", "shed")


def build_batch_spans(events: List[TraceTuple]) -> Dict[int, dict]:
    """Fold batch-scoped events into one record per batch id."""
    batches: Dict[int, dict] = {}
    for ev in events:
        bid = ev[EV_BATCH]
        if bid < 0:
            continue
        rec = batches.get(bid)
        if rec is None:
            rec = batches[bid] = {
                "batch": bid, "endpoint": ev[EV_ENDPOINT], "dispatched": None,
                "end": None, "outcome": None, "size": 0, "cause": "",
                "retries": 0, "hedges": 0, "faults": 0, "attempts": 0,
                "retry_overhead": 0.0, "breaker_wait": 0.0, "members": [],
            }
        kind = ev[EV_KIND]
        if kind == "dispatched":
            rec["dispatched"] = ev[EV_T]
            rec["size"] = ev[EV_SIZE]
            rec["cause"] = ev[EV_DETAIL]
            if ev[EV_ENDPOINT]:
                rec["endpoint"] = ev[EV_ENDPOINT]
        elif kind == "batched":
            # columnar membership event: req slot is the member-id tuple
            rec["members"].extend(ev[EV_REQ])
        elif kind == "retry":
            rec["retries"] += 1
            rec["retry_overhead"] += ev[EV_VALUE]
        elif kind == "hedge":
            rec["hedges"] += 1
        elif kind == "fault":
            rec["faults"] += 1
        elif kind == "attempt":
            rec["attempts"] += 1
        elif kind == "breaker_wait":
            rec["breaker_wait"] += ev[EV_VALUE]
        elif kind in _TERMINAL_BATCH:
            rec["end"] = ev[EV_T]
            rec["outcome"] = kind
    return batches


def build_request_spans(events: List[TraceTuple]) -> List[dict]:
    """One record per request with the per-stage budget breakdown.

    ``queue_wait`` runs from queue entry to batch formation; the
    queue-entry instant is the ``admitted`` timestamp when a frontend is
    in the loop, else the arrival time the resolving ``batched`` /
    ``expired`` / ``shed`` event carries in its value slot (there is no
    per-arrival event on the hot path). ``service`` runs from batch
    dispatch to batch resolution and includes any retries —
    ``retry_overhead``/``breaker_wait`` say how much of it was spent
    re-trying rather than serving.
    """
    batches = build_batch_spans(events)
    reqs: Dict[int, dict] = {}
    for ev in events:
        kind = ev[EV_KIND]
        if kind == "batched":
            # columnar membership event: fan the member-id / arrival
            # tuples back out into one record per member
            t, bid, endpoint = ev[EV_T], ev[EV_BATCH], ev[EV_ENDPOINT]
            for rid, arrival in zip(ev[EV_REQ], ev[EV_VALUE]):
                rec = reqs.get(rid)
                if rec is None:
                    rec = reqs[rid] = {
                        "req_id": rid, "endpoint": endpoint,
                        "start": t, "batched": None,
                        "batch": -1, "end": None, "outcome": None,
                    }
                elif endpoint and not rec["endpoint"]:
                    rec["endpoint"] = endpoint
                rec["batched"] = t
                rec["batch"] = bid
                if 0.0 < arrival < rec["start"]:
                    rec["start"] = arrival
            continue
        rid = ev[EV_REQ]
        if rid < 0:
            continue
        rec = reqs.get(rid)
        if rec is None:
            rec = reqs[rid] = {
                "req_id": rid, "endpoint": ev[EV_ENDPOINT],
                "start": ev[EV_T], "batched": None,
                "batch": -1, "end": None, "outcome": None,
            }
        if ev[EV_ENDPOINT] and not rec["endpoint"]:
            rec["endpoint"] = ev[EV_ENDPOINT]
        if kind in _TERMINAL_REQ:
            rec["end"] = ev[EV_T]
            rec["outcome"] = kind
        if kind in _QUEUE_ANCHORED:
            # value is the queue-entry arrival time (0.0 when the
            # emitter did not know it, e.g. a submit-time brownout drop)
            v = ev[EV_VALUE]
            if 0.0 < v < rec["start"]:
                rec["start"] = v

    rows: List[dict] = []
    for rid in sorted(reqs):
        rec = reqs[rid]
        batch = batches.get(rec["batch"])
        end = rec["end"]
        outcome = rec["outcome"]
        if batch is not None and outcome is None:
            end = batch["end"]
            outcome = batch["outcome"]
        queue_end = rec["batched"] if rec["batched"] is not None else end
        queue_wait = (queue_end - rec["start"]
                      if queue_end is not None else None)
        service = None
        if batch is not None and batch["dispatched"] is not None \
                and batch["end"] is not None:
            service = batch["end"] - batch["dispatched"]
        rows.append({
            "req_id": rid,
            "endpoint": rec["endpoint"],
            "arrival": rec["start"],
            "queue_wait": queue_wait,
            "service": service,
            "e2e": (end - rec["start"]) if end is not None else None,
            "outcome": outcome or "inflight",
            "batch": rec["batch"],
            "batch_size": batch["size"] if batch else 0,
            "retries": batch["retries"] if batch else 0,
            "hedges": batch["hedges"] if batch else 0,
            "retry_overhead": batch["retry_overhead"] if batch else 0.0,
            "breaker_wait": batch["breaker_wait"] if batch else 0.0,
        })
    return rows


# ------------------------------------------------------------------ chrome
def chrome_trace(events: List[TraceTuple]) -> dict:
    """Chrome ``trace_event`` document (the JSON Object Format).

    pid 1 = request lanes, pid 2 = batch lanes. Durations use "X"
    complete events; point-in-time markers (faults, retries, hedges,
    breaker transitions) use "i" instant events. Timestamps are
    microseconds per the trace_event spec.
    """
    out: List[dict] = [
        {"ph": "M", "pid": 1, "name": "process_name",
         "args": {"name": "requests"}},
        {"ph": "M", "pid": 2, "name": "process_name",
         "args": {"name": "batches"}},
    ]
    us = 1e6
    for row in build_request_spans(events):
        tid = row["req_id"]
        t0 = row["arrival"] * us
        if row["queue_wait"] is not None:
            out.append({"ph": "X", "pid": 1, "tid": tid, "name": "queue",
                        "cat": "request", "ts": t0,
                        "dur": row["queue_wait"] * us,
                        "args": {"endpoint": row["endpoint"],
                                 "outcome": row["outcome"]}})
        if row["service"] is not None and row["queue_wait"] is not None:
            out.append({"ph": "X", "pid": 1, "tid": tid, "name": "service",
                        "cat": "request",
                        "ts": t0 + row["queue_wait"] * us,
                        "dur": row["service"] * us,
                        "args": {"batch": row["batch"],
                                 "retries": row["retries"]}})
    for bid in sorted(b := build_batch_spans(events)):
        rec = b[bid]
        if rec["dispatched"] is None:
            continue
        dur = ((rec["end"] - rec["dispatched"]) * us
               if rec["end"] is not None else 0.0)
        out.append({"ph": "X", "pid": 2, "tid": bid,
                    "name": f"batch[{rec['size']}] {rec['cause']}",
                    "cat": "batch", "ts": rec["dispatched"] * us, "dur": dur,
                    "args": {"endpoint": rec["endpoint"],
                             "outcome": rec["outcome"],
                             "retries": rec["retries"],
                             "members": rec["members"]}})
    for ev in events:
        if ev[EV_KIND] in ("fault", "retry", "hedge", "breaker_wait",
                           "breaker_open", "rejected", "shed", "expired"):
            out.append({"ph": "i", "pid": 2,
                        "tid": ev[EV_BATCH] if ev[EV_BATCH] >= 0 else 0,
                        "name": ev[EV_KIND], "cat": "event", "s": "g",
                        "ts": ev[EV_T] * us,
                        "args": {"endpoint": ev[EV_ENDPOINT],
                                 "detail": ev[EV_DETAIL],
                                 "value": ev[EV_VALUE]}})
    return {"traceEvents": out, "displayTimeUnit": "ms"}


# ------------------------------------------------------------------ writers
REQUEST_CSV_FIELDS = ("req_id", "endpoint", "arrival", "queue_wait",
                      "service", "e2e", "outcome", "batch", "batch_size",
                      "retries", "hedges", "retry_overhead", "breaker_wait")


def write_chrome_trace(path: str, events: List[TraceTuple]) -> str:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    with open(path, "w") as fh:
        json.dump(chrome_trace(events), fh, sort_keys=True)
    return path


def write_request_csv(path: str, events: List[TraceTuple]) -> str:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    with open(path, "w", newline="") as fh:
        w = csv.DictWriter(fh, fieldnames=REQUEST_CSV_FIELDS)
        w.writeheader()
        for row in build_request_spans(events):
            w.writerow(row)
    return path
