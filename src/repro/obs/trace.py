"""Ring-buffered lifecycle span tracer.

The tracer records flat events; span *trees* are reconstructed offline
by :mod:`repro.obs.export`. Keeping the hot path to "build one tuple,
append to a deque" is what makes the ≤10% tracing-on overhead budget on
``bench_proxy_overhead`` reachable, and keeping events as plain tuples
(not objects) keeps the ring cache-friendly at six-figure capacities.

Events are 8-tuples indexed by the ``EV_*`` constants:

    (t, kind, endpoint, req_id, batch, size, value, detail)

- ``t`` comes from whatever clock the caller holds (sim time or
  ``Clock.now()``) — the tracer itself never reads a wall clock, so a
  ``FakeClock`` run produces byte-identical event streams across runs.
- ``req_id``/``batch`` are -1 when the event is not request- or
  batch-scoped. Batch ids are handed out by :meth:`Tracer.next_batch_id`
  and stamped onto ``Batch.trace_id`` at dispatch, which is how retry /
  hedge / completion events in the drivers correlate back to the
  ``dispatched`` event and its ``batched`` membership event.
- ``batched`` is the one columnar kind: ONE event per dispatched batch
  whose req slot holds the *tuple* of member request ids and whose
  value slot holds the matching tuple of member arrival (queue-entry)
  times. Per-member events would dominate the tracing-on overhead
  budget — the ring retention is the measured cost — so membership is
  packed into two tuples per batch instead.
- ``value`` elsewhere carries an optional float payload (backoff
  seconds on ``retry``, wait seconds on ``breaker_wait``, latency on
  terminal events, and — on ``expired``/``shed`` — the request's
  queue-entry ``arrival_time``, which is how exporters anchor the
  queue-wait span without a per-arrival hot-path event); ``detail``
  carries a short string (dispatch cause, fault kind, error type).

The request lifecycle, as kinds:

    admitted -> expired | shed | batched   (queue entry in ev value)
    batched  -> (per batch) dispatched -> (attempt | fault | retry |
                 hedge | breaker_wait)* -> completed | timed_out | failed

plus ``rejected`` for admission-control drops that never reach a queue
and ``breaker_open`` for circuit transitions.
"""
from __future__ import annotations

from collections import deque
from typing import Deque, List, Tuple, Union

# Tuple field indices (events are plain tuples for speed).
EV_T = 0
EV_KIND = 1
EV_ENDPOINT = 2
EV_REQ = 3
EV_BATCH = 4
EV_SIZE = 5
EV_VALUE = 6
EV_DETAIL = 7

# req/value slots are scalars everywhere except the columnar "batched"
# kind, where they hold the member-id / member-arrival tuples.
TraceTuple = Tuple[float, str, str, Union[int, Tuple[int, ...]], int, int,
                   Union[float, Tuple[float, ...]], str]

#: Every kind the instrumented modules emit, in rough lifecycle order.
SPAN_KINDS = (
    "admitted",      # frontend accepted the request (deadline attached)
    "rejected",      # admission control turned the request away
    "expired",       # dead on queue: deadline passed before dispatch
    "shed",          # dropped by load shedding / brownout
    "batched",       # batch membership: member ids in req slot (tuple),
                     # member arrival times in value slot (tuple)
    "dispatched",    # batch handed to the dispatch_fn (cause in detail)
    "routed",        # SpilloverRouter picked a fleet tier for the batch
                     # (detail = "tier:reason", e.g. "fast:inflight_cap")
    "attempt",       # platform/target attempt started
    "fault",         # injected or upstream fault (kind in detail)
    "retry",         # driver re-submitting a failed batch (backoff in value)
    "hedge",         # speculative duplicate dispatch
    "breaker_wait",  # batch held at an open circuit (wait secs in value)
    "breaker_open",  # circuit transitioned to open
    "completed",     # batch finished; requests resolved
    "timed_out",     # batch resolved past its deadline
    "failed",        # batch exhausted retries / cancelled at drain
)


class Tracer:
    """Bounded ring of lifecycle events.

    ``capacity`` bounds memory; once full, the oldest events are evicted
    (``dropped`` counts evictions so exporters can flag truncation).

    ``buf`` is deliberately public: the per-request emission site on the
    proxy decision path (``BatchQueue._dispatch``) inlines the append
    instead of calling :meth:`emit` — one Python call per request is
    what separates passing and failing the ≤10% overhead gate. The
    inlined form must stay semantically identical to :meth:`emit`::

        buf = tracer.buf
        if len(buf) == tracer.capacity:
            tracer.dropped += 1
        buf.append((t, kind, endpoint, req_id, batch, size, value, detail))
    """

    __slots__ = ("capacity", "dropped", "buf", "_batch_seq")

    def __init__(self, capacity: int = 1 << 16) -> None:
        if capacity <= 0:
            raise ValueError(f"capacity must be positive, got {capacity}")
        self.capacity = capacity
        self.dropped = 0
        self.buf: Deque[TraceTuple] = deque(maxlen=capacity)
        self._batch_seq = 0

    # ------------------------------------------------------------- hot path
    def emit(self, t: float, kind: str, endpoint: str = "",
             req_id: int = -1, batch: int = -1, size: int = 0,
             value: float = 0.0, detail: str = "") -> None:
        buf = self.buf
        if len(buf) == self.capacity:
            self.dropped += 1
        buf.append((t, kind, endpoint, req_id, batch, size, value, detail))

    def next_batch_id(self) -> int:
        """Monotonic id stamped on ``Batch.trace_id`` at dispatch."""
        self._batch_seq += 1
        return self._batch_seq

    # ------------------------------------------------------------- reading
    def __len__(self) -> int:
        return len(self.buf)

    def events(self) -> List[TraceTuple]:
        return list(self.buf)

    def clear(self) -> None:
        self.buf.clear()
        self.dropped = 0
        self._batch_seq = 0


def serialize_events(events: List[TraceTuple]) -> bytes:
    """Canonical byte encoding of an event stream.

    Used by determinism tests: two FakeClock runs with the same seed
    must serialize to identical bytes. ``repr`` of floats is exact
    (shortest round-trip representation), so this is a faithful canonical
    form, not a lossy pretty-print.
    """
    return "\n".join(repr(ev) for ev in events).encode()
