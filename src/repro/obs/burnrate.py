"""Multi-window SLO burn-rate meters (SRE-style fast/slow burn).

The SLA grants an *error budget*: a pXX latency target allows a
``1 - XX/100`` fraction of requests to violate the SLO (a p95 target
budgets 5% violations). The burn rate is the windowed violation rate
divided by that budget — burn 1.0 means violations are arriving exactly
at the budgeted pace; burn 20 means the budget for the window is being
consumed 20x too fast.

Two windows, per the classic multi-window alerting scheme: a *fast*
window (default 60 s) catches sharp regressions quickly, a *slow*
window (default 600 s) filters blips. ``burning`` is true only when
both exceed 1.0 — fast for responsiveness, slow for confirmation.

Implementation is a coarse bucketed ring (no per-sample storage): each
``record`` lands in a time bucket of width ``resolution`` and old
buckets are pruned, so memory is O(slow_window / resolution) regardless
of request rate, and everything is exact integer counting — fully
deterministic under ``FakeClock``.
"""
from __future__ import annotations

from collections import deque
from typing import Deque, List


class BurnRateMeter:
    __slots__ = ("budget", "fast_window", "slow_window", "resolution",
                 "_buckets", "total", "violations")

    def __init__(self, budget: float, fast_window: float = 60.0,
                 slow_window: float = 600.0, resolution: float = 0.0) -> None:
        if budget <= 0:
            raise ValueError(f"error budget must be positive, got {budget}")
        if fast_window <= 0 or slow_window < fast_window:
            raise ValueError("need 0 < fast_window <= slow_window, got "
                             f"{fast_window}/{slow_window}")
        self.budget = budget
        self.fast_window = fast_window
        self.slow_window = slow_window
        self.resolution = resolution if resolution > 0 else fast_window / 12.0
        # each bucket: [bucket_index, violations, total]
        self._buckets: Deque[List[float]] = deque()
        self.total = 0
        self.violations = 0

    @classmethod
    def for_percentile(cls, percentile: float, **kwargs) -> "BurnRateMeter":
        """Budget from an SLA percentile: p95 → 5% allowed violations.

        A p100 target has zero budget; clamp to 0.1% so the burn rate
        stays finite (it then reads "violations per 0.1% budget")."""
        return cls(max(1.0 - percentile / 100.0, 1e-3), **kwargs)

    # ------------------------------------------------------------- record
    def record(self, now: float, violated: bool) -> None:
        idx = int(now // self.resolution)
        buckets = self._buckets
        v = 1 if violated else 0
        if buckets and idx <= buckets[-1][0]:
            # same bucket, or a slightly out-of-order timestamp: fold into
            # the newest bucket rather than breaking monotonicity.
            buckets[-1][1] += v
            buckets[-1][2] += 1
        else:
            buckets.append([idx, v, 1])
            floor = idx - int(self.slow_window // self.resolution) - 1
            while buckets and buckets[0][0] < floor:
                buckets.popleft()
        self.total += 1
        self.violations += v

    # --------------------------------------------------------------- read
    def _window_rate(self, now: float, window: float) -> float:
        floor = (now - window) / self.resolution
        viol = total = 0
        for idx, v, n in reversed(self._buckets):
            if idx < floor:
                break
            viol += v
            total += n
        return viol / total if total else 0.0

    def rates(self, now: float) -> dict:
        fast = self._window_rate(now, self.fast_window) / self.budget
        slow = self._window_rate(now, self.slow_window) / self.budget
        return {
            "burn_rate_fast": fast,
            "burn_rate_slow": slow,
            "burning": fast > 1.0 and slow > 1.0,
        }

    # ------------------------------------------------------ fault tolerance
    def snapshot(self) -> dict:
        return {"buckets": [list(b) for b in self._buckets],
                "total": self.total, "violations": self.violations}

    def restore(self, state: dict) -> None:
        self._buckets = deque([list(b) for b in state.get("buckets", [])])
        self.total = state.get("total", 0)
        self.violations = state.get("violations", 0)
