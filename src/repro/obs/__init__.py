"""Unified observability plane shared by the sim and live worlds.

One instrumentation surface rides on the unmodified core:

- :mod:`repro.obs.trace` — ring-buffered lifecycle span tracer
  (``admitted → expired|shed|batched → dispatched →
  (retry|hedge|breaker_wait)* → completed|timed_out|failed``), emitted
  from hooks in ``BatchQueue``, ``ProxyFrontend``, ``AsyncProxyServer``,
  ``ServerlessPlatform`` and ``FaultyTarget``.
- :mod:`repro.obs.export` — exporters: Chrome ``trace_event`` JSON
  (open in chrome://tracing or Perfetto) and a flat per-request CSV
  with the queue-wait / service / retry-overhead breakdown.
- :mod:`repro.obs.metrics` — typed ``Counter``/``Gauge``/``Histogram``
  in a central ``MetricsRegistry``; existing hand-rolled ledger counters
  bind into it via each component's ``register_metrics``.
- :mod:`repro.obs.burnrate` — multi-window SLO burn-rate meters
  (fast/slow burn a la SRE alerting).
- :mod:`repro.obs.recorder` — bounded flight recorder that dumps a JSON
  postmortem on conservation failure, drain timeout, or breaker-open.

Everything is deterministic under ``FakeClock`` (no wall-clock reads,
no RNG) and zero-cost when disabled: every emission site in the
instrumented modules is guarded by ``if tracer is not None``.
"""
from repro.obs.burnrate import BurnRateMeter
from repro.obs.export import (build_batch_spans, build_request_spans,
                              chrome_trace, write_chrome_trace,
                              write_request_csv)
from repro.obs.metrics import Counter, Gauge, Histogram, MetricsRegistry
from repro.obs.recorder import FlightRecorder
from repro.obs.trace import (EV_BATCH, EV_DETAIL, EV_ENDPOINT, EV_KIND,
                             EV_REQ, EV_SIZE, EV_T, EV_VALUE, SPAN_KINDS,
                             Tracer, serialize_events)

__all__ = [
    "BurnRateMeter",
    "Counter",
    "EV_BATCH",
    "EV_DETAIL",
    "EV_ENDPOINT",
    "EV_KIND",
    "EV_REQ",
    "EV_SIZE",
    "EV_T",
    "EV_VALUE",
    "FlightRecorder",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "SPAN_KINDS",
    "Tracer",
    "build_batch_spans",
    "build_request_spans",
    "chrome_trace",
    "serialize_events",
    "write_chrome_trace",
    "write_request_csv",
]
