"""Typed metrics in a central registry.

Three primitive types plus a *bound* metric:

- :class:`Counter` — monotonically increasing int.
- :class:`Gauge` — last-write-wins float (with a high-water helper).
- :class:`Histogram` — fixed-bound bucket counts + sum/count.
- bound metrics (:meth:`MetricsRegistry.bind`) — a zero-cost adapter
  over an existing hand-rolled counter: the owning object keeps its
  plain ``self.x += 1`` hot path and exposes the value to the registry
  through a callable, so migrating the platform/runtime ledgers costs
  nothing on the dispatch path and cannot perturb byte-identical logs.

``snapshot()``/``restore()`` round-trip owned metrics losslessly; bound
metrics are materialized into snapshots but (by design) not restored —
their source of truth is the bound object, which has its own
snapshot/restore path.
"""
from __future__ import annotations

from typing import Callable, Dict, List, Optional, Sequence

DEFAULT_BOUNDS = (0.001, 0.005, 0.01, 0.025, 0.05, 0.1, 0.25, 0.5,
                  1.0, 2.5, 5.0, 10.0)


class Counter:
    """Monotonic counter. ``inc`` with a negative amount is an error."""

    __slots__ = ("name", "value")

    def __init__(self, name: str) -> None:
        self.name = name
        self.value = 0

    def inc(self, amount: int = 1) -> None:
        if amount < 0:
            raise ValueError(f"counter {self.name}: negative inc {amount}")
        self.value += amount

    def __repr__(self) -> str:
        return f"Counter({self.name}={self.value})"


class Gauge:
    """Point-in-time value; ``update_max`` keeps a high-water mark."""

    __slots__ = ("name", "value")

    def __init__(self, name: str) -> None:
        self.name = name
        self.value = 0.0

    def set(self, value: float) -> None:
        self.value = value

    def update_max(self, value: float) -> None:
        if value > self.value:
            self.value = value

    def __repr__(self) -> str:
        return f"Gauge({self.name}={self.value})"


class Histogram:
    """Cumulative-style histogram over fixed upper bounds.

    ``counts[i]`` counts observations ``<= bounds[i]``; the final slot
    is the overflow bucket. ``total``/``count`` give the mean for free.
    """

    __slots__ = ("name", "bounds", "counts", "count", "total")

    def __init__(self, name: str,
                 bounds: Sequence[float] = DEFAULT_BOUNDS) -> None:
        if list(bounds) != sorted(bounds):
            raise ValueError(f"histogram {name}: bounds must be sorted")
        self.name = name
        self.bounds = tuple(bounds)
        self.counts = [0] * (len(self.bounds) + 1)
        self.count = 0
        self.total = 0.0

    def observe(self, value: float) -> None:
        i = 0
        bounds = self.bounds
        n = len(bounds)
        while i < n and value > bounds[i]:
            i += 1
        self.counts[i] += 1
        self.count += 1
        self.total += value

    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else 0.0

    def __repr__(self) -> str:
        return f"Histogram({self.name}: n={self.count}, mean={self.mean:.6g})"


class MetricsRegistry:
    """Central name → metric table shared by a proxy/driver instance.

    ``counter``/``gauge``/``histogram`` are get-or-create (idempotent,
    so components can register eagerly without coordination); ``bind``
    registers a read-only callable over an external counter.
    """

    __slots__ = ("_counters", "_gauges", "_histograms", "_bound")

    def __init__(self) -> None:
        self._counters: Dict[str, Counter] = {}
        self._gauges: Dict[str, Gauge] = {}
        self._histograms: Dict[str, Histogram] = {}
        self._bound: Dict[str, Callable[[], float]] = {}

    # ------------------------------------------------------------- create
    def counter(self, name: str) -> Counter:
        c = self._counters.get(name)
        if c is None:
            self._check_fresh(name, self._counters)
            c = self._counters[name] = Counter(name)
        return c

    def gauge(self, name: str) -> Gauge:
        g = self._gauges.get(name)
        if g is None:
            self._check_fresh(name, self._gauges)
            g = self._gauges[name] = Gauge(name)
        return g

    def histogram(self, name: str,
                  bounds: Optional[Sequence[float]] = None) -> Histogram:
        h = self._histograms.get(name)
        if h is None:
            self._check_fresh(name, self._histograms)
            h = self._histograms[name] = Histogram(
                name, bounds if bounds is not None else DEFAULT_BOUNDS)
        return h

    def bind(self, name: str, source: Callable[[], float]) -> None:
        """Register a read-only view over an externally owned counter."""
        self._check_fresh(name, self._bound)
        self._bound[name] = source

    def _check_fresh(self, name: str, own: dict) -> None:
        for table in (self._counters, self._gauges, self._histograms,
                      self._bound):
            if table is not own and name in table:
                raise ValueError(f"metric {name!r} already registered "
                                 "with a different type")

    # -------------------------------------------------------------- read
    def names(self) -> List[str]:
        return sorted(set(self._counters) | set(self._gauges)
                      | set(self._histograms) | set(self._bound))

    def value(self, name: str) -> float:
        if name in self._counters:
            return self._counters[name].value
        if name in self._gauges:
            return self._gauges[name].value
        if name in self._bound:
            return self._bound[name]()
        if name in self._histograms:
            return self._histograms[name].count
        raise KeyError(name)

    def snapshot(self) -> dict:
        return {
            "counters": {n: c.value for n, c in sorted(self._counters.items())},
            "gauges": {n: g.value for n, g in sorted(self._gauges.items())},
            "histograms": {
                n: {"bounds": list(h.bounds), "counts": list(h.counts),
                    "count": h.count, "total": h.total}
                for n, h in sorted(self._histograms.items())},
            "bound": {n: fn() for n, fn in sorted(self._bound.items())},
        }

    def restore(self, state: dict) -> None:
        for name, value in state.get("counters", {}).items():
            self.counter(name).value = value
        for name, value in state.get("gauges", {}).items():
            self.gauge(name).value = value
        for name, hs in state.get("histograms", {}).items():
            h = self.histogram(name, hs.get("bounds"))
            h.counts = list(hs.get("counts", h.counts))
            h.count = hs.get("count", 0)
            h.total = hs.get("total", 0.0)
