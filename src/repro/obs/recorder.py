"""Crash-dump flight recorder.

A bounded ring of recent structured events that the drivers note into
at coarse-grained points (dispatches, failures, retries, breaker
transitions, sheds), dumped to a JSON postmortem when something goes
wrong. Trigger sites, wired in the drivers:

- conservation-assert failure (``AsyncProxyServer.assert_conserved`` /
  ``ServerlessPlatform.assert_conserved``),
- drain timeout (stragglers cancelled at shutdown),
- circuit breaker opening.

Dumps are numbered sequentially (never timestamped — no wall-clock
reads, so FakeClock runs stay deterministic) and dumping never raises:
a postmortem writer that can crash the run it is documenting would be
worse than no postmortem.
"""
from __future__ import annotations

import json
import os
from collections import deque
from typing import Deque, List, Optional

DEFAULT_DUMP_DIR = os.path.join("experiments", "results", "obs")


class FlightRecorder:
    __slots__ = ("capacity", "out_dir", "dropped", "dumps", "_buf", "_seq")

    def __init__(self, capacity: int = 2048,
                 out_dir: str = DEFAULT_DUMP_DIR) -> None:
        if capacity <= 0:
            raise ValueError(f"capacity must be positive, got {capacity}")
        self.capacity = capacity
        self.out_dir = out_dir
        self.dropped = 0
        self.dumps: List[str] = []
        self._buf: Deque[dict] = deque(maxlen=capacity)
        self._seq = 0

    # ------------------------------------------------------------- hot path
    def note(self, t: float, kind: str, **fields) -> None:
        """Record one structured event (fields must be JSON-friendly)."""
        buf = self._buf
        if len(buf) == self.capacity:
            self.dropped += 1
        fields["t"] = t
        fields["kind"] = kind
        buf.append(fields)

    def __len__(self) -> int:
        return len(self._buf)

    def events(self) -> List[dict]:
        return list(self._buf)

    # ---------------------------------------------------------------- dump
    def dump(self, reason: str, now: float = 0.0,
             extra: Optional[dict] = None) -> Optional[str]:
        """Write the ring to a JSON postmortem; returns the path.

        Swallows I/O errors (returns None) — the recorder must never
        turn a diagnosed failure into a new one."""
        self._seq += 1
        safe = "".join(c if c.isalnum() or c in "-_" else "-" for c in reason)
        path = os.path.join(self.out_dir,
                            f"flightrec-{self._seq:03d}-{safe}.json")
        payload = {
            "reason": reason,
            "now": now,
            "dropped": self.dropped,
            "extra": extra or {},
            "events": list(self._buf),
        }
        try:
            os.makedirs(self.out_dir, exist_ok=True)
            with open(path, "w") as fh:
                json.dump(payload, fh, sort_keys=True)
        except OSError:
            return None
        self.dumps.append(path)
        return path
